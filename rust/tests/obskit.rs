//! Integration: the obskit contract end to end (DESIGN.md §13). Arming
//! every sink must not change *simulation results* — per-job records and
//! the run integrals are compared byte-for-byte against an obs-off run of
//! the same trace for all seven policies — and the written artifacts must
//! be non-empty, schema-clean, and (for the Chrome trace) globally
//! timestamp-ordered.

use std::path::PathBuf;

use wise_share::cluster::{Cluster, ClusterConfig};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::JobState;
use wise_share::perf::interference::InterferenceModel;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sim::engine::{self, SimOutcome};
use wise_share::sim::EngineConfig;
use wise_share::util::json::Json;
use wise_share::{Obs, ObsConfig};

const N_JOBS: usize = 240;
const SEED: u64 = 17;

fn run_policy(name: &str, obs: Obs) -> SimOutcome {
    let jobs = trace::generate(&TraceConfig::simulation(N_JOBS, SEED));
    let mut p = sched::by_name(name).expect("registered policy");
    engine::run_cluster_obs(
        Cluster::new(ClusterConfig::simulation()),
        &jobs,
        InterferenceModel::new(),
        p.as_mut(),
        EngineConfig::default(),
        obs,
    )
    .expect("simulation run")
}

/// Byte-exact view of everything the simulation *computed* (as opposed to
/// observed): per-job records plus the outcome scalars. Debug formatting
/// prints f64s exactly enough to distinguish any bit-level drift.
fn fingerprint(out: &SimOutcome) -> String {
    format!(
        "{:?}|{:?}|{:?}|{:?}|{:?}|{:?}|{:?}",
        out.jobs,
        out.makespan_s,
        out.policy_calls,
        out.preemptions,
        out.busy_gpu_s,
        out.shared_gpu_s,
        out.total_gpus
    )
}

fn artifact_dir(policy: &str) -> PathBuf {
    let slug: String = policy
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect();
    std::env::temp_dir().join(format!("wise-share-obskit-{}-{slug}", std::process::id()))
}

#[test]
fn sinks_on_vs_off_results_are_byte_identical_and_artifacts_validate() {
    for name in POLICY_NAMES {
        let dir = artifact_dir(name);
        let cfg = ObsConfig {
            trace: Some(dir.join("trace.json")),
            metrics: Some(dir.join("metrics.json")),
            audit: Some(dir.join("audit.jsonl")),
            sample_every_s: 300.0,
        };
        let obs = Obs::new(cfg);
        assert!(obs.is_enabled());

        let off = run_policy(name, Obs::disabled());
        let on = run_policy(name, obs.clone());
        assert_eq!(
            fingerprint(&off),
            fingerprint(&on),
            "{name}: armed sinks changed simulation results"
        );

        // Completion events observed == jobs the simulation finished.
        let finished =
            on.jobs.iter().filter(|j| j.state == JobState::Finished).count() as u64;
        assert!(finished > 0, "{name}: nothing finished — trace too small to test");
        assert_eq!(
            obs.counter("events/completion"),
            Some(finished),
            "{name}: completion counter disagrees with the outcome"
        );
        assert!(
            obs.histogram_samples(&format!("on_event_latency/{name}"))
                .is_some_and(|s| !s.is_empty()),
            "{name}: no on_event latency histogram recorded"
        );

        obs.finish().expect("writing artifacts");

        // Chrome trace: parses through the first-party JSON layer, has
        // events, and is globally ts-ordered (metadata records carry no
        // timestamp).
        let text = std::fs::read_to_string(dir.join("trace.json")).unwrap();
        let doc = Json::parse(&text).expect("chrome trace parses");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty(), "{name}: empty trace");
        let ts: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() != Some("M"))
            .map(|e| e.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert!(!ts.is_empty());
        assert!(
            ts.windows(2).all(|w| w[0] <= w[1]),
            "{name}: trace events not timestamp-ordered"
        );

        // Sibling JSONL stream: every line is one JSON object.
        let jsonl = std::fs::read_to_string(dir.join("trace.jsonl")).unwrap();
        assert!(!jsonl.trim().is_empty());
        for line in jsonl.lines() {
            Json::parse(line).expect("trace jsonl line parses");
        }

        // Metrics document: schema-tagged, with the latency histogram.
        let text = std::fs::read_to_string(dir.join("metrics.json")).unwrap();
        let doc = Json::parse(&text).expect("metrics json parses");
        assert_eq!(
            doc.get("schema").unwrap().as_str(),
            Some(wise_share::obskit::metrics::METRICS_SCHEMA)
        );
        assert!(doc
            .get("histograms")
            .unwrap()
            .get(&format!("on_event_latency/{name}"))
            .is_some());

        // Audit log: every line parses, applied txns are recorded, and
        // SJF-BSBF's Algorithm-2 scoring shows up per candidate pair.
        let audit = std::fs::read_to_string(dir.join("audit.jsonl")).unwrap();
        let kinds: Vec<String> = audit
            .lines()
            .map(|l| {
                Json::parse(l)
                    .expect("audit line parses")
                    .get("kind")
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .to_string()
            })
            .collect();
        assert!(kinds.iter().any(|k| k == "apply"), "{name}: no applied txns logged");
        if name == "SJF-BSBF" {
            assert!(kinds.iter().any(|k| k == "alg2"), "no Algorithm-2 audit lines");
        }

        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn disabled_handle_writes_nothing() {
    let dir = artifact_dir("disabled-probe");
    let off = Obs::disabled();
    assert!(!off.is_enabled());
    run_policy("SJF-BSBF", off.clone());
    off.finish().unwrap();
    assert!(!dir.exists(), "a disabled handle must not touch the filesystem");
    // And an all-None config is the disabled handle, not an armed no-op.
    assert!(!Obs::new(ObsConfig::default()).is_enabled());
}

//! serve end-to-end: the daemon protocol's conformance contract
//! (DESIGN.md §14).
//!
//! * every malformed or invalid request yields a structured error
//!   response with a machine-readable `code` — never a panic,
//! * admission control: duplicate ids, infeasible gangs, `busy`
//!   backpressure past `--max-pending` (with the `rejected` event),
//! * a scripted session is deterministic: same requests, byte-identical
//!   output,
//! * snapshot → resume is lossless: `query` output is byte-identical
//!   across the cycle, and a resumed daemon replays the same remaining
//!   completion stream as the uninterrupted run,
//! * the CLI rejects non-positive intervals/ratios at parse time with
//!   the flag's name in the error.

use std::path::PathBuf;
use std::process::Command;

use wise_share::obskit::Obs;
use wise_share::serve::{proto, ClusterSpec, Daemon, HandleOutcome, LoadConfig, ServeConfig};
use wise_share::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wise-share-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A virtual-clock daemon on the 16×4 simulation cluster.
fn daemon(policy: &str, max_pending: usize) -> Daemon {
    let cfg = ServeConfig {
        policy: policy.to_string(),
        max_pending,
        ..ServeConfig::default()
    };
    Daemon::new(cfg, Obs::disabled()).unwrap()
}

fn submit(id: u64, model: &str, gpus: usize, iterations: u64, batch: u32) -> String {
    format!(
        "{{\"op\":\"submit\",\"id\":{id},\"model\":\"{model}\",\"gpus\":{gpus},\
         \"iterations\":{iterations},\"batch\":{batch}}}"
    )
}

fn advance_to(t: f64) -> String {
    format!("{{\"op\":\"advance\",\"to\":{t}}}")
}

/// The response is always the last line; parse it.
fn response(out: &HandleOutcome) -> Json {
    let last = out.lines.last().unwrap_or_else(|| panic!("no output lines"));
    Json::parse(last).unwrap_or_else(|e| panic!("unparseable response {last:?}: {e}"))
}

fn code(out: &HandleOutcome) -> String {
    let r = response(out);
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "expected a failed response");
    r.get("code").and_then(|c| c.as_str()).expect("failed response has a code").to_string()
}

fn assert_ok(out: &HandleOutcome) -> Json {
    let r = response(out);
    assert_eq!(
        r.get("ok").and_then(Json::as_bool),
        Some(true),
        "expected ok, got {:?}",
        out.lines.last()
    );
    r
}

fn events_of(lines: &[String], kind: &str) -> Vec<Json> {
    lines
        .iter()
        .filter_map(|l| Json::parse(l).ok())
        .filter(|j| {
            j.get("type").and_then(|t| t.as_str()) == Some("event")
                && j.get("event").and_then(|e| e.as_str()) == Some(kind)
        })
        .collect()
}

#[test]
fn malformed_and_unknown_requests_get_structured_errors() {
    let mut d = daemon("SJF-BSBF", 64);
    // Truncated JSON, a non-object, a missing op: all E_PARSE.
    for bad in ["{\"op\": \"sub", "[1, 2, 3]", "{\"id\": 4}", "42"] {
        let out = d.handle_line(bad);
        assert_eq!(out.lines.len(), 1, "{bad:?} -> {:?}", out.lines);
        assert_eq!(code(&out), proto::E_PARSE, "{bad:?}");
        assert!(!out.exit);
    }
    // Unknown op names the known ones.
    let out = d.handle_line("{\"op\":\"frobnicate\"}");
    assert_eq!(code(&out), proto::E_UNKNOWN_OP);
    let err = response(&out).get("error").unwrap().as_str().unwrap().to_string();
    assert!(err.contains("submit") && err.contains("drain"), "{err}");
    // Missing / malformed submit fields.
    let out = d.handle_line("{\"op\":\"submit\"}");
    assert_eq!(code(&out), proto::E_BAD_REQUEST);
    let out = d.handle_line("{\"op\":\"submit\",\"id\":1,\"model\":\"nope\",\"gpus\":1}");
    assert_eq!(code(&out), proto::E_BAD_REQUEST);
    let err = response(&out).get("error").unwrap().as_str().unwrap().to_string();
    assert!(err.contains("CIFAR10"), "unknown model should list the known ones: {err}");
    // Zero-sized dimensions and bad est_factor.
    let out = d.handle_line(&submit(1, "CIFAR10", 0, 100, 32));
    assert_eq!(code(&out), proto::E_BAD_REQUEST);
    let out = d.handle_line(
        "{\"op\":\"submit\",\"id\":1,\"model\":\"CIFAR10\",\"gpus\":1,\
         \"iterations\":100,\"batch\":32,\"est_factor\":-2.0}",
    );
    assert_eq!(code(&out), proto::E_BAD_REQUEST);
    // Empty lines are ignored outright.
    let out = d.handle_line("   ");
    assert!(out.lines.is_empty() && !out.exit);
    // And the daemon is still healthy after all of that.
    assert_ok(&d.handle_line("{\"op\":\"query\"}"));
}

#[test]
fn duplicate_unknown_and_finished_ids() {
    let mut d = daemon("SJF", 64);
    let out = d.handle_line(&submit(7, "CIFAR10", 1, 200, 32));
    let r = assert_ok(&out);
    assert_eq!(r.get("id").and_then(Json::as_u64), Some(7));
    // The arrival-now job starts before the response comes back.
    assert_eq!(events_of(&out.lines, "started").len(), 1);
    // Same client id again: rejected without touching the first.
    let out = d.handle_line(&submit(7, "CIFAR10", 1, 200, 32));
    assert_eq!(code(&out), proto::E_DUPLICATE_ID);
    // Cancel of a job nobody submitted.
    let out = d.handle_line("{\"op\":\"cancel\",\"id\":99}");
    assert_eq!(code(&out), proto::E_UNKNOWN_JOB);
    let out = d.handle_line("{\"op\":\"query\",\"id\":99}");
    assert_eq!(code(&out), proto::E_UNKNOWN_JOB);
    // Run job 7 to completion, then cancel it: already-finished.
    let out = d.handle_line(&advance_to(20_000.0));
    assert_ok(&out);
    assert_eq!(events_of(&out.lines, "completed").len(), 1);
    let out = d.handle_line("{\"op\":\"cancel\",\"id\":7}");
    assert_eq!(code(&out), proto::E_FINISHED);
    // Cancelling a cancelled job is also already-finished.
    let out = d.handle_line(&submit(8, "CIFAR10", 1, 500_000, 32));
    assert_ok(&out);
    assert_ok(&d.handle_line("{\"op\":\"cancel\",\"id\":8}"));
    let out = d.handle_line("{\"op\":\"cancel\",\"id\":8}");
    assert_eq!(code(&out), proto::E_FINISHED);
    let r = assert_ok(&d.handle_line("{\"op\":\"query\",\"id\":8}"));
    let status = r.get("job").unwrap().get("status").unwrap().as_str().unwrap();
    assert_eq!(status, "cancelled");
}

#[test]
fn backpressure_rejects_busy_past_max_pending() {
    // SJF does not share GPUs, so a second whole-cluster gang must queue.
    let mut d = daemon("SJF", 1);
    assert_ok(&d.handle_line(&submit(1, "CIFAR10", 64, 100_000, 32)));
    assert_ok(&d.handle_line(&submit(2, "CIFAR10", 64, 100_000, 32)));
    let r = assert_ok(&d.handle_line("{\"op\":\"query\"}"));
    assert_eq!(r.get("running").and_then(Json::as_usize), Some(1));
    // One queued job = the --max-pending bound: the third submit bounces.
    let out = d.handle_line(&submit(3, "CIFAR10", 1, 100, 32));
    assert_eq!(code(&out), proto::E_BUSY);
    let rej = events_of(&out.lines, "rejected");
    assert_eq!(rej.len(), 1);
    assert_eq!(rej[0].get("id").and_then(Json::as_u64), Some(3));
    assert_eq!(rej[0].get("code").and_then(|c| c.as_str()), Some(proto::E_BUSY));
    // The rejected id was never admitted — it can be resubmitted later.
    let out = d.handle_line("{\"op\":\"query\",\"id\":3}");
    assert_eq!(code(&out), proto::E_UNKNOWN_JOB);
    // Cancelling the queued job frees the slot.
    assert_ok(&d.handle_line("{\"op\":\"cancel\",\"id\":2}"));
    assert_ok(&d.handle_line(&submit(3, "CIFAR10", 1, 100, 32)));
}

#[test]
fn infeasible_gangs_are_rejected_up_front() {
    let mut d = daemon("SJF-BSBF", 64);
    // More GPUs than the simulation cluster (16×4) has.
    let out = d.handle_line(&submit(1, "CIFAR10", 65, 100, 32));
    assert_eq!(code(&out), proto::E_INFEASIBLE);
    // An arrival in the past is a client error, not time travel.
    let mut d = daemon("SJF-BSBF", 64);
    assert_ok(&d.handle_line(&advance_to(100.0)));
    let out = d.handle_line(
        "{\"op\":\"submit\",\"id\":1,\"model\":\"CIFAR10\",\"gpus\":1,\
         \"iterations\":100,\"batch\":32,\"arrival_s\":5.0}",
    );
    assert_eq!(code(&out), proto::E_BAD_REQUEST);
}

#[test]
fn advance_validation_and_snapshot_path_requirement() {
    let mut d = daemon("SJF-BSBF", 64);
    for bad in [
        "{\"op\":\"advance\"}",
        "{\"op\":\"advance\",\"to\":5.0,\"dt\":5.0}",
        "{\"op\":\"advance\",\"to\":1e30}",
    ] {
        let out = d.handle_line(bad);
        assert_eq!(code(&out), proto::E_BAD_REQUEST, "{bad:?}");
    }
    assert_ok(&d.handle_line(&advance_to(50.0)));
    let out = d.handle_line(&advance_to(10.0));
    assert_eq!(code(&out), proto::E_BAD_REQUEST, "advance must not move backwards");
    // snapshot with neither a request path nor --snapshot.
    let out = d.handle_line("{\"op\":\"snapshot\"}");
    assert_eq!(code(&out), proto::E_BAD_REQUEST);
}

#[test]
fn drain_completes_everything_and_refuses_new_work() {
    let mut d = daemon("SJF", 64);
    for id in 1..=4u64 {
        assert_ok(&d.handle_line(&submit(id, "CIFAR10", 8, 2_000 * id, 64)));
    }
    assert_ok(&d.handle_line("{\"op\":\"cancel\",\"id\":2}"));
    let out = d.handle_line("{\"op\":\"drain\"}");
    let r = assert_ok(&out);
    assert!(out.exit, "drain ends the session");
    assert_eq!(r.get("completed").and_then(Json::as_usize), Some(3));
    assert_eq!(r.get("cancelled").and_then(Json::as_usize), Some(1));
    assert_eq!(events_of(&out.lines, "completed").len(), 3);
    // Draining (and drained) daemons admit nothing.
    let out = d.handle_line(&submit(9, "CIFAR10", 1, 100, 32));
    assert_eq!(code(&out), proto::E_DRAINING);
}

/// The scripted session the determinism guarantee is pinned on: same
/// seedless virtual-clock script, byte-identical output.
fn session_script() -> Vec<String> {
    let mut s = vec![
        submit(1, "CIFAR10", 8, 8_000, 64),
        submit(2, "BERT", 16, 400, 16),
        submit(3, "ImageNet", 16, 900, 64),
        advance_to(30.0),
        submit(4, "NCF", 4, 30_000, 256),
        submit(5, "DeepSpeech2", 8, 1_500, 32),
        "{\"op\":\"cancel\",\"id\":3}".to_string(),
        advance_to(120.0),
        submit(6, "YoloV3", 8, 2_500, 16),
        "{\"op\":\"query\"}".to_string(),
    ];
    s.push("{\"op\":\"drain\"}".to_string());
    s
}

fn run_script(d: &mut Daemon, script: &[String]) -> Vec<String> {
    let mut all = Vec::new();
    for line in script {
        all.extend(d.handle_line(line).lines);
    }
    all
}

#[test]
fn scripted_sessions_are_deterministic() {
    let script = session_script();
    let a = run_script(&mut daemon("SJF-BSBF", 64), &script);
    let b = run_script(&mut daemon("SJF-BSBF", 64), &script);
    assert_eq!(a, b, "same script, same daemon config: byte-identical output");
    assert!(!a.is_empty());
}

#[test]
fn snapshot_resume_roundtrips_query_byte_identically() {
    let path = tmp("roundtrip.json");
    let mut d = daemon("SJF", 64);
    for id in 1..=5u64 {
        assert_ok(&d.handle_line(&submit(id, "CIFAR10", 16, 40_000, 64)));
    }
    assert_ok(&d.handle_line("{\"op\":\"cancel\",\"id\":4}"));
    assert_ok(&d.handle_line(&advance_to(200.0)));
    let r = assert_ok(
        &d.handle_line(&format!("{{\"op\":\"snapshot\",\"path\":{:?}}}", path.display())),
    );
    assert_eq!(r.get("path").and_then(|p| p.as_str()), Some(&*path.display().to_string()));
    // The same queries against the original and the resumed daemon.
    let mut queries = vec!["{\"op\":\"query\"}".to_string()];
    queries.extend((1..=5u64).map(|id| format!("{{\"op\":\"query\",\"id\":{id}}}")));
    let before: Vec<String> =
        queries.iter().flat_map(|q| d.handle_line(q).lines).collect();
    let mut r = Daemon::resume(&path, None, Obs::disabled()).unwrap();
    let after: Vec<String> =
        queries.iter().flat_map(|q| r.handle_line(q).lines).collect();
    assert_eq!(before, after, "query output must survive snapshot -> resume byte-for-byte");
    // The atomic write leaves no temp file behind.
    assert!(!PathBuf::from(format!("{}.tmp", path.display())).exists());
}

#[test]
fn resume_replays_the_same_remaining_completion_stream() {
    let path = tmp("replay.json");
    // Durations spread so that at the snapshot instant the shortest job
    // is certainly done and the longest certainly is not.
    let prefix: Vec<String> = (1..=10u64)
        .map(|id| submit(id, "CIFAR10", 16, 2_000 + 15_000 * (id - 1), 64))
        .collect();
    let mid = advance_to(1_500.0);

    // Uninterrupted run: prefix, advance, drain.
    let mut a = daemon("SJF", 64);
    run_script(&mut a, &prefix);
    a.handle_line(&mid);
    let tail_a = a.handle_line("{\"op\":\"drain\"}");
    assert!(tail_a.exit);

    // Interrupted run: same prefix + advance, snapshot, resume, drain.
    let mut b = daemon("SJF", 64);
    run_script(&mut b, &prefix);
    b.handle_line(&mid);
    assert_ok(&b.handle_line(&format!("{{\"op\":\"snapshot\",\"path\":{:?}}}", path.display())));
    drop(b);
    let mut c = Daemon::resume(&path, None, Obs::disabled()).unwrap();
    let tail_c = c.handle_line("{\"op\":\"drain\"}");
    assert!(tail_c.exit);

    assert_eq!(
        tail_a.lines, tail_c.lines,
        "a resumed daemon must finish the session exactly like the uninterrupted one"
    );
    // The mid-session snapshot caught a genuinely partial state (some
    // jobs done, some not), or this test proves nothing.
    let done_early = events_of(&tail_a.lines, "completed").len();
    assert!(done_early > 0 && done_early < 10, "{done_early} of 10 completed after resume");
}

#[test]
fn resume_rejects_garbage_snapshots() {
    let path = tmp("bad-snapshot.json");
    std::fs::write(&path, "{\"schema\": \"somebody-elses-v7\"}").unwrap();
    let err = Daemon::resume(&path, None, Obs::disabled()).unwrap_err().to_string();
    assert!(err.contains("unsupported schema"), "{err}");
    std::fs::write(&path, "not json at all").unwrap();
    assert!(Daemon::resume(&path, None, Obs::disabled()).is_err());
    assert!(Daemon::resume(&tmp("missing.json"), None, Obs::disabled()).is_err());
}

#[test]
fn serve_load_runs_a_small_session_end_to_end() {
    let cfg = LoadConfig {
        jobs: 24,
        seed: 7,
        cluster: ClusterSpec::Preset("simulation".to_string()),
        ..LoadConfig::default()
    };
    let out = wise_share::serve::load::run(&cfg, Obs::disabled()).unwrap();
    assert_eq!(out.submitted, 24);
    assert_eq!(out.accepted + out.rejected_busy, 24);
    assert_eq!(out.completed, out.accepted, "drain finishes every accepted job");
    assert!(out.makespan_s > 0.0);
    assert_eq!(out.decision_latencies_s.len(), 24);
    assert!(out.latency_p50_s <= out.latency_p95_s && out.latency_p95_s <= out.latency_p99_s);
    let text = out.summary();
    assert!(text.contains("24 submitted"), "{text}");
    // And the session is deterministic in the sim domain.
    let again = wise_share::serve::load::run(&cfg, Obs::disabled()).unwrap();
    assert_eq!(out.completed, again.completed);
    assert_eq!(out.makespan_s, again.makespan_s);
    assert_eq!(out.latency_p99_s, again.latency_p99_s);
}

// ------------------------------------------------------------ CLI layer

fn run_cli(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_wise-share"))
        .args(args)
        .output()
        .expect("spawning wise-share");
    (out.status.success(), String::from_utf8_lossy(&out.stderr).into_owned())
}

#[test]
fn cli_rejects_non_positive_intervals_at_parse_time() {
    // (argv, the flag the error must name)
    let cases: &[(&[&str], &str)] = &[
        (&["simulate", "--sample-every", "0"], "--sample-every"),
        (&["simulate", "--load", "0"], "--load"),
        (&["serve", "--snapshot-every", "0"], "--snapshot-every"),
        (&["serve", "--snapshot-every", "-3"], "--snapshot-every"),
        (&["serve", "--time-compression", "0"], "--time-compression"),
        (&["serve", "--max-pending", "0"], "--max-pending"),
        (&["serve", "--snapshot-every", "5"], "--snapshot"),
        (&["serve", "--resume", "/nonexistent.json", "--policy", "SJF"], "--policy"),
        (&["serve-load", "--load", "-1"], "--load"),
        (&["serve-load", "--workload", "nope"], "workload preset"),
    ];
    for (argv, needle) in cases {
        let (ok, stderr) = run_cli(argv);
        assert!(!ok, "{argv:?} must fail");
        assert!(stderr.contains(needle), "{argv:?}: stderr {stderr:?} lacks {needle:?}");
    }
}

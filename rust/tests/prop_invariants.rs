//! Property-based tests over the coordinator's core invariants (first-party
//! `util::prop` harness; seeds are reported on failure for replay).
//!
//! Covered properties:
//! * cluster allocation/release conservation + share-cap under random ops,
//! * free-capacity index (buckets / nonempty / tier totals) vs rescan,
//! * Theorem 1 endpoint optimality against randomized interior κ,
//! * Algorithm 2 memory feasibility + accumulation-step arithmetic,
//! * Eq. 7 monotonicity in batch / accumulation / interference,
//! * end-to-end engine conservation over random small traces,
//! * JSON parser round-trip over random documents.

use wise_share::cluster::{topology, AllocView, Cluster, ClusterConfig, FreeIndex};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::{JobRecord, JobSpec, JobState};
use wise_share::pair::{batch_size_scaling, best_pair_schedule, PairSide};
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::{ModelKind, WorkloadProfile};
use wise_share::prop_assert;
use wise_share::sched;
use wise_share::sim::engine;
use wise_share::util::json::Json;
use wise_share::util::prop::forall;
use wise_share::util::rng::Rng;

const CASES: usize = 64;

#[test]
fn prop_cluster_alloc_release_conserves_slots() {
    forall("cluster-conservation", 0xC1u64, CASES, |rng| {
        let mut cluster = Cluster::new(ClusterConfig::physical());
        let mut live: Vec<usize> = Vec::new();
        for op in 0..40 {
            if !live.is_empty() && rng.f64() < 0.4 {
                let job = live.swap_remove(rng.index(live.len()));
                cluster.release(job);
            } else {
                // Try to allocate 1-4 GPUs with a free share slot.
                let want = 1 + rng.index(4);
                let candidates: Vec<usize> = (0..cluster.total_gpus())
                    .filter(|&g| cluster.load(g) < 2)
                    .collect();
                if candidates.len() < want {
                    continue;
                }
                let job = 1000 + op;
                let gpus: Vec<usize> = candidates[..want].to_vec();
                cluster.allocate(job, &gpus);
                live.push(job);
            }
            if let Err(e) = cluster.check_invariants() {
                return Err(format!("invariant broken: {e}"));
            }
        }
        // Release everything: cluster must be fully free again.
        for job in live {
            cluster.release(job);
        }
        prop_assert!(
            cluster.free_gpus().len() == cluster.total_gpus(),
            "slots leaked after full release"
        );
        Ok(())
    });
}

/// The incrementally maintained free-capacity index (buckets, nonempty
/// list, per-memory-tier free totals) must equal a from-scratch rescan
/// after every random allocate/release — on a uniform topology and on the
/// two-tier heterogeneous one, where `eligible_total` actually gates.
#[test]
fn prop_free_index_matches_rescan_under_random_ops() {
    forall("free-index-rescan", 0xF1u64, CASES, |rng| {
        let mut cluster = if rng.f64() < 0.5 {
            Cluster::new(ClusterConfig::physical())
        } else {
            Cluster::with_topology(topology::by_name("hetero-16x4-2tier").unwrap())
        };
        let n_servers = cluster.topology().n_servers();
        let mut live: Vec<usize> = Vec::new();
        for op in 0..60 {
            if !live.is_empty() && rng.f64() < 0.4 {
                let job = live.swap_remove(rng.index(live.len()));
                cluster.release(job);
            } else {
                let want = 1 + rng.index(4);
                let candidates: Vec<usize> = (0..cluster.total_gpus())
                    .filter(|&g| cluster.load(g) < 2)
                    .collect();
                if candidates.len() < want {
                    continue;
                }
                let job = 2000 + op;
                cluster.allocate(job, &candidates[..want]);
                live.push(job);
            }
            let free: Vec<usize> =
                (0..n_servers).map(|s| cluster.server_free(s)).collect();
            let idx = AllocView::free_index(&cluster);
            prop_assert!(
                *idx == FreeIndex::build(cluster.topology(), &free),
                "op {op}: incremental index != rebuild (free {free:?})"
            );
            for k in 1..=idx.max_free() {
                let want: Vec<usize> =
                    (0..n_servers).filter(|&s| free[s] == k).collect();
                prop_assert!(
                    idx.bucket(k) == want.as_slice(),
                    "op {op}: bucket[{k}] {:?} != rescan {want:?}",
                    idx.bucket(k)
                );
            }
            let want_nonempty: Vec<usize> =
                (0..n_servers).filter(|&s| free[s] > 0).collect();
            prop_assert!(
                idx.nonempty() == want_nonempty.as_slice(),
                "op {op}: nonempty {:?} != rescan {want_nonempty:?}",
                idx.nonempty()
            );
            prop_assert!(
                idx.eligible_total(0.0) == cluster.free_count(),
                "op {op}: eligible_total(0) != free_count"
            );
            for probe in [11.0, 15.0, 22.0] {
                let want: usize = (0..cluster.total_gpus())
                    .filter(|&g| cluster.load(g) == 0 && cluster.mem_gb(g) + 1e-9 >= probe)
                    .count();
                prop_assert!(
                    idx.eligible_total(probe) == want,
                    "op {op}: eligible_total({probe}) {} != rescan {want}",
                    idx.eligible_total(probe)
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_theorem1_endpoints_dominate_interior() {
    forall("theorem1-endpoints", 0x71u64, 256, |rng| {
        let t_a = 0.05 + rng.f64();
        let t_b = 0.05 + rng.f64();
        let i_a = 10.0 + rng.f64() * 5000.0;
        let i_b = 10.0 + rng.f64() * 5000.0;
        let xa = 1.0 + rng.f64() * 3.0;
        let xb = 1.0 + rng.f64() * 3.0;
        let best = best_pair_schedule(
            PairSide { iter_time: t_a, iters: i_a, xi: xa },
            PairSide { iter_time: t_b, iters: i_b, xi: xb },
        );
        // Interior κ: B alone for κ, then overlap.
        for _ in 0..8 {
            let kappa = rng.f64() * t_b * i_b;
            let rem_b = i_b - kappa / t_b;
            let (ta_h, tb_h) = (t_a * xa, t_b * xb);
            let (fin_a, fin_b) = if ta_h * i_a <= tb_h * rem_b {
                let fa = kappa + ta_h * i_a;
                let done_b = (fa - kappa) / tb_h;
                (fa, fa + t_b * (rem_b - done_b))
            } else {
                let fb = kappa + tb_h * rem_b;
                let done_a = (fb - kappa) / ta_h;
                (fb + t_a * (i_a - done_a), fb)
            };
            let interior = 0.5 * (fin_a + fin_b);
            prop_assert!(
                best.avg_jct <= interior + 1e-6,
                "interior κ={kappa:.3} gives {interior:.3} < best {:.3} \
                 (t_a={t_a:.3} t_b={t_b:.3} i_a={i_a:.0} i_b={i_b:.0} ξ=({xa:.2},{xb:.2}))",
                best.avg_jct
            );
        }
        Ok(())
    });
}

#[test]
fn prop_alg2_configuration_always_memory_feasible() {
    let kinds = ModelKind::ALL;
    forall("alg2-memory", 0xA2u64, 256, |rng| {
        let new_kind = *rng.choose(&kinds);
        let run_kind = *rng.choose(&kinds);
        let new_batch = [1u32, 2, 4, 8, 16, 32, 64, 128][rng.index(8)];
        let mut mk = |kind: ModelKind, batch: u32| {
            JobRecord::new(JobSpec {
                id: 0,
                model: kind,
                gpus: 4,
                iterations: 100 + rng.index(5000) as u64,
                batch,
                arrival_s: 0.0,
                est_factor: 1.0,
            })
        };
        let new = mk(new_kind, new_batch);
        let run_batch = WorkloadProfile::get(run_kind).default_batch;
        let run = mk(run_kind, run_batch);
        let xi = InterferenceModel::new();
        if let Some(cfg) = batch_size_scaling(&new, &run, 4, 11.0, &xi) {
            let new_mem = new.spec.profile().mem.mem_gb(cfg.sub_batch as f64);
            let run_mem = run.spec.profile().mem.mem_gb(run_batch as f64);
            prop_assert!(
                new_mem + run_mem <= 11.0 + 1e-9,
                "{:?}+{:?}: joint {:.2} GB over budget (sub {})",
                new_kind,
                run_kind,
                new_mem + run_mem,
                cfg.sub_batch
            );
            prop_assert!(
                cfg.sub_batch <= new_batch && cfg.sub_batch >= 1,
                "sub-batch {} outside [1, {new_batch}]",
                cfg.sub_batch
            );
            prop_assert!(
                cfg.accum_step == (new_batch as f64 / cfg.sub_batch as f64).ceil() as u32,
                "accum {} != ceil({new_batch}/{})",
                cfg.accum_step,
                cfg.sub_batch
            );
        }
        Ok(())
    });
}

#[test]
fn prop_eq7_monotonicity() {
    forall("eq7-monotone", 0xE7u64, 256, |rng| {
        let kind = *rng.choose(&ModelKind::ALL);
        let perf = WorkloadProfile::get(kind).perf;
        let b = 2.0 + rng.f64() * 62.0;
        let n = 1 + rng.index(16);
        // monotone in batch
        prop_assert!(
            perf.iter_time(b * 2.0, 1, n) >= perf.iter_time(b, 1, n),
            "{kind:?}: iter time must grow with batch"
        );
        // accumulation adds (s-1) sub-passes: never faster
        prop_assert!(
            perf.iter_time(b, 4, n) >= perf.iter_time(b, 2, n) - 1e-12,
            "{kind:?}: accumulation cannot speed up an iteration"
        );
        // throughput positive and finite
        let phi = perf.throughput(b, 1, n);
        prop_assert!(phi.is_finite() && phi > 0.0, "{kind:?}: bad throughput {phi}");
        Ok(())
    });
}

#[test]
fn prop_engine_conserves_work_over_random_traces() {
    let policies = ["FIFO", "SJF", "SJF-FFS", "SJF-BSBF"];
    forall("engine-conservation", 0xE6u64, 24, |rng| {
        let n = 8 + rng.index(24);
        let seed = rng.next_u64();
        let jobs = trace::generate(&TraceConfig::simulation(n, seed));
        let name = *rng.choose(&policies);
        let mut p = sched::by_name(name).unwrap();
        let out = engine::run(
            ClusterConfig::simulation(),
            &jobs,
            InterferenceModel::new(),
            p.as_mut(),
        )
        .map_err(|e| format!("{name} failed: {e:#}"))?;
        for j in &out.jobs {
            prop_assert!(j.state == JobState::Finished, "{name}: unfinished job");
            prop_assert!(
                j.jct().unwrap() >= j.spec.solo_runtime(1) * 0.999,
                "{name}: job {} finished faster than physics allows",
                j.spec.id
            );
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 1e3),
            3 => {
                let n = rng.index(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            *rng.choose(&['a', 'é', '"', '\\', '\n', 'z', '7', ' '])
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.index(5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.index(5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json-roundtrip", 0x15u64, 512, |rng| {
        let doc = gen_value(rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).map_err(|e| format!("parse failed: {e:#}\n{text}"))?;
        prop_assert!(back == doc, "roundtrip mismatch:\n{text}\n{back:?}");
        Ok(())
    });
}

#[test]
fn prop_trace_generator_wellformed() {
    forall("trace-wellformed", 0x7Au64, 64, |rng| {
        let n = 1 + rng.index(100);
        let jobs = trace::generate(&TraceConfig::simulation(n, rng.next_u64()));
        prop_assert!(jobs.len() == n, "wrong job count");
        let mut prev = 0.0;
        for j in &jobs {
            prop_assert!(j.arrival_s >= prev, "arrivals must be sorted");
            prev = j.arrival_s;
            prop_assert!(j.gpus >= 1 && j.gpus <= 16, "bad gang width {}", j.gpus);
            let mem = j.profile().mem.mem_gb(j.batch as f64);
            prop_assert!(
                mem <= 11.0,
                "{:?} batch {} solo-infeasible: {mem:.1} GB",
                j.model,
                j.batch
            );
        }
        Ok(())
    });
}

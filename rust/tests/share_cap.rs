//! k-way sharing-set tests (DESIGN.md §17).
//!
//! * **C = 2 golden parity**: the k-way generalization must be invisible
//!   at the paper's pair cap. `SJF-BSBF-k` at C = 2 is *byte-identical*
//!   to `SJF-BSBF` on the 240-job/64-GPU paper trace, and an explicit
//!   `with_max_share(2)` cluster is byte-identical to the default path
//!   for all seven policies — the refactor's equivalence guarantee.
//! * **Composition properties**: a composed ξ collapses bit-for-bit to
//!   the pair factor at one aggressor (so every C = 2 code path is
//!   unaffected by the [`Composition`] choice) and never decreases when
//!   an aggressor is added, under both composition rules.
//! * **Eq. 9 at C = 3**: the transaction layer admits a third resident
//!   only within the k-way memory budget — a full-batch third job is
//!   rejected, the same job fits after gradient accumulation shrinks
//!   its sub-batch, and a fourth job trips the C cap itself.

use wise_share::cluster::{Cluster, ClusterConfig};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::{JobRecord, JobSpec, JobState};
use wise_share::perf::interference::{Composition, InterferenceModel};
use wise_share::perf::profiles::ModelKind;
use wise_share::prop_assert;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sim::engine::{self, EngineConfig, SimOutcome};
use wise_share::sim::SimState;
use wise_share::sched_core::{SchedContext, Txn};
use wise_share::util::prop::forall;

/// Every observable of an outcome, with f64s captured as raw bits so the
/// comparison is byte-exact, not epsilon-close.
fn fingerprint(out: &SimOutcome) -> Vec<(u64, u64, u64, u64, u32, Vec<usize>, u8)> {
    out.jobs
        .iter()
        .map(|j| {
            (
                j.finish_s.unwrap_or(f64::NAN).to_bits(),
                j.first_start_s.unwrap_or(f64::NAN).to_bits(),
                j.queued_s.to_bits(),
                j.remaining_iters.to_bits(),
                j.accum_step,
                j.gpus_held.clone(),
                match j.state {
                    JobState::Pending => 0,
                    JobState::Running => 1,
                    JobState::Preempted => 2,
                    JobState::Finished => 3,
                },
            )
        })
        .collect()
}

#[test]
fn golden_sjf_bsbf_k_at_c2_is_byte_identical_to_sjf_bsbf() {
    // At the paper's pair cap the k-way policy *is* the pair policy: same
    // candidate order, same Theorem-1 arithmetic (share_set delegates to
    // the pair path at one resident), same gang assembly — pinned on the
    // full 240-job paper trace.
    let jobs = trace::generate(&TraceConfig::simulation(240, 1));
    let mut pair = sched::by_name("SJF-BSBF").unwrap();
    let a = engine::run(ClusterConfig::simulation(), &jobs, InterferenceModel::new(), pair.as_mut())
        .unwrap();
    let mut kway = sched::by_name("SJF-BSBF-k").unwrap();
    let b = engine::run(ClusterConfig::simulation(), &jobs, InterferenceModel::new(), kway.as_mut())
        .unwrap();
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "makespan diverged");
    assert_eq!(a.policy_calls, b.policy_calls, "policy calls diverged");
    assert_eq!(a.preemptions, b.preemptions, "preemptions diverged");
    assert_eq!(fingerprint(&a), fingerprint(&b), "job records diverged");
}

#[test]
fn golden_explicit_c2_cap_matches_default_for_all_policies() {
    // `with_max_share(2)` must be a no-op relative to the default config
    // for every policy — the share-cap knob cannot perturb the C = 2
    // baseline it generalizes.
    let jobs = trace::generate(&TraceConfig::simulation(240, 1));
    for name in POLICY_NAMES {
        let mut p1 = sched::by_name(name).unwrap();
        let default = engine::run(
            ClusterConfig::simulation(),
            &jobs,
            InterferenceModel::new(),
            p1.as_mut(),
        )
        .unwrap();
        let mut p2 = sched::by_name(name).unwrap();
        let capped = engine::run_cluster(
            Cluster::new(ClusterConfig::simulation()).with_max_share(2),
            &jobs,
            InterferenceModel::new(),
            p2.as_mut(),
            EngineConfig::default(),
        )
        .unwrap();
        assert_eq!(
            default.makespan_s.to_bits(),
            capped.makespan_s.to_bits(),
            "{name}: makespan diverged"
        );
        assert_eq!(default.policy_calls, capped.policy_calls, "{name}: policy calls");
        assert_eq!(default.preemptions, capped.preemptions, "{name}: preemptions");
        assert_eq!(fingerprint(&default), fingerprint(&capped), "{name}: job records diverged");
    }
}

#[test]
fn prop_composition_collapses_to_pair_factor_at_one_aggressor() {
    // Identity at k = 1 is what keeps every pair (C = 2) code path
    // bit-for-bit independent of the composition rule.
    forall("xi-set-collapse", 0x5E7, 256, |rng| {
        let m = if rng.f64() < 0.25 {
            InterferenceModel::with_global(1.0 + 2.0 * rng.f64())
        } else {
            InterferenceModel::new()
        };
        let victim = ModelKind::ALL[rng.index(ModelKind::ALL.len())];
        let aggressor = ModelKind::ALL[rng.index(ModelKind::ALL.len())];
        let pair = m.xi(victim, aggressor);
        for comp in [Composition::MaxDegradation, Composition::PairwiseProduct] {
            let set = m.xi_set(victim, [aggressor], comp);
            prop_assert!(
                set.to_bits() == pair.to_bits(),
                "{comp:?}: xi_set {set} != pair xi {pair} for \
                 ({victim:?}, {aggressor:?})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_composition_never_decreases_when_an_aggressor_is_added() {
    // Monotonicity: more co-runners can only slow a victim down — under
    // either rule, and from any starting set (including empty, where the
    // composed factor is 1).
    forall("xi-set-monotone", 0x5E8, 256, |rng| {
        let m = InterferenceModel::new();
        let victim = ModelKind::ALL[rng.index(ModelKind::ALL.len())];
        let base: Vec<ModelKind> = (0..rng.index(4))
            .map(|_| ModelKind::ALL[rng.index(ModelKind::ALL.len())])
            .collect();
        let extra = ModelKind::ALL[rng.index(ModelKind::ALL.len())];
        let mut grown = base.clone();
        grown.push(extra);
        for comp in [Composition::MaxDegradation, Composition::PairwiseProduct] {
            let before = m.xi_set(victim, base.iter().copied(), comp);
            let after = m.xi_set(victim, grown.iter().copied(), comp);
            prop_assert!(before >= 1.0, "{comp:?}: composed xi {before} < 1");
            prop_assert!(
                after >= before,
                "{comp:?}: adding {extra:?} to {base:?} decreased xi for \
                 {victim:?}: {before} -> {after}"
            );
        }
        Ok(())
    });
}

/// A 1-GPU Cifar10@128 job record (4.3 GB at full batch) with id `id`,
/// already arrived.
fn cifar_job(id: usize) -> JobRecord {
    JobRecord::new(JobSpec {
        id,
        model: ModelKind::Cifar10,
        gpus: 1,
        iterations: 1000,
        batch: 128,
        arrival_s: 0.0,
        est_factor: 1.0,
    })
}

#[test]
fn eq9_admits_a_third_resident_only_within_the_kway_budget() {
    // Three Cifar10@128 residents want 3 x 4.3 = 12.9 GB on an 11 GB GPU:
    // the transaction layer must reject the full-batch third start, accept
    // it once gradient accumulation shrinks the sub-batch (Eq. 9), and
    // reject a fourth start on the C = 3 cap itself.
    let state = SimState {
        now: 0.0,
        cluster: Cluster::new(ClusterConfig::simulation()).with_max_share(3),
        jobs: (0..4).map(cifar_job).collect(),
        xi: InterferenceModel::new(),
        not_before: vec![0.0; 4],
        service_gpu_s: vec![0.0; 4],
    };
    let mut ctx = SchedContext::from_state(state);

    // Two residents fit at full batch (8.6 GB <= 11 GB).
    for job in [0usize, 1] {
        let mut txn = Txn::new();
        txn.start(job, vec![0], 1);
        ctx.apply(&txn, 0.0).unwrap_or_else(|e| panic!("job {job} must start: {e:#}"));
    }

    // Full-batch third resident: 12.9 GB > 11 GB — Eq. 9 rejects.
    let mut over = Txn::new();
    over.start(2, vec![0], 1);
    let err = format!("{:#}", ctx.apply(&over, 0.0).unwrap_err());
    assert!(err.contains("memory over budget"), "wrong rejection: {err}");

    // Same job at accum_step 4 (sub-batch 32, 1.9 GB): 10.5 GB fits.
    let mut accum = Txn::new();
    accum.start(2, vec![0], 4);
    ctx.apply(&accum, 0.0).expect("accumulated third resident fits Eq. 9");
    assert_eq!(ctx.cluster.slot(0).jobs.len(), 3);

    // A fourth job trips the share cap, not the memory check.
    let mut fourth = Txn::new();
    fourth.start(3, vec![0], 4);
    let err = format!("{:#}", ctx.apply(&fourth, 0.0).unwrap_err());
    assert!(err.contains("share capacity C = 3"), "wrong rejection: {err}");
}

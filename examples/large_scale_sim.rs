//! Large-scale trace-driven simulation — regenerates the paper's Tables III
//! and IV plus the Fig. 5 series in one run.
//!
//! * Table III: 240 jobs at baseline arrival density.
//! * Table IV: 480 jobs at 2x density (the paper samples more jobs from the
//!   same busiest period, so the arrival *rate* doubles).
//! * Fig. 5a: JCT CDF points per policy; Fig. 5b: queueing by model.
//!
//! Run: `cargo run --release --example large_scale_sim`

use wise_share::cluster::ClusterConfig;
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::perf::interference::InterferenceModel;
use wise_share::report;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sim::{engine, metrics};

fn run_table(n_jobs: usize, load: f64, seed: u64, label: &str) -> anyhow::Result<()> {
    let mut tcfg = TraceConfig::simulation(n_jobs, seed);
    tcfg.load_factor = load;
    let jobs = trace::generate(&tcfg);
    let mut rows = Vec::new();
    for name in POLICY_NAMES {
        let mut p = sched::by_name(name).unwrap();
        let out = engine::run(
            ClusterConfig::simulation(),
            &jobs,
            InterferenceModel::new(),
            p.as_mut(),
        )?;
        rows.push(metrics::summarize(name, &out.jobs, out.makespan_s));

        if label == "Table III" {
            // Fig. 5a: JCT CDF (decimated to ~20 points per policy).
            let cdf = metrics::jct_cdf(&out.jobs);
            let step = (cdf.len() / 20).max(1);
            let pts: Vec<(f64, f64)> =
                cdf.iter().step_by(step).map(|&(t, f)| (t, f)).collect();
            print!("{}", report::csv_series(&format!("fig5a,{name}"), &pts));
            // Fig. 5b: queueing by model.
            let by: Vec<(f64, f64)> = metrics::queueing_by_model(&out.jobs)
                .iter()
                .enumerate()
                .map(|(i, (_, q))| (i as f64, *q))
                .collect();
            print!("{}", report::csv_series(&format!("fig5b,{name}"), &by));
        }
    }
    println!("\n=== {label} ({n_jobs} jobs, load x{load}) ===");
    println!("{}", report::table34(&rows));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    run_table(240, 1.0, 1, "Table III")?;
    run_table(480, 2.0, 1, "Table IV")?;
    Ok(())
}

//! Large-scale trace-driven simulation — regenerates the paper's Tables III
//! and IV through the [`wise_share::campaign`] subsystem instead of a
//! hand-rolled per-table loop:
//!
//! * Table III: 240 jobs at baseline arrival density (load ×1).
//! * Table IV: 480 jobs at 2× density — expressed declaratively via the
//!   `jobs_scale_load_baseline` axis knob (the paper samples more jobs
//!   from the same busiest period, so the arrival *rate* doubles).
//!
//! Each cell runs every policy over 3 trace seeds on a worker pool and is
//! reported seed-averaged with 95% CIs. The Fig. 5a/5b CSV series that
//! used to piggyback here live in `cargo bench --bench figures`.
//!
//! Run: `cargo run --release --example large_scale_sim`

use wise_share::campaign::{self, Axes, CampaignSpec};
use wise_share::sched::POLICY_NAMES;

fn main() -> anyhow::Result<()> {
    let mut spec = CampaignSpec::new("tables34");
    spec.policies = POLICY_NAMES.iter().map(|s| s.to_string()).collect();
    spec.axes = Axes {
        load_factors: vec![1.0],
        job_counts: vec![240, 480], // Table III, Table IV
        gpu_counts: Vec::new(),     // the 16×4 simulation cluster
        topologies: Vec::new(),
        workloads: Vec::new(),      // philly-sim, the paper trace shape
        estimators: Vec::new(),     // oracle durations, as the paper assumes
        share_caps: Vec::new(),     // the paper's C = 2
        seeds: vec![1, 2, 3],
        jobs_scale_load_baseline: Some(240), // 480 jobs ⇒ 2× density
    };
    let res = campaign::execute(&spec, 0)?;
    print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
    println!("{} runs in {:.1}s wall", res.n_runs, res.wall_s);
    if res.n_failures > 0 {
        anyhow::bail!("{} of {} runs failed (see FAILED lines above)", res.n_failures, res.n_runs);
    }
    Ok(())
}

//! Share-cap sweep: mean queueing delay vs the k-way sharing cap C
//! (DESIGN.md §17).
//!
//! Runs the `small-job-flood` preset — bursty arrivals of short,
//! memory-light jobs, the workload where pair sharing (the paper's
//! C = 2) leaves admission capacity on the table — over the campaign
//! `share_caps` axis for the three sharing-aware policies:
//!
//! * **SJF-BSBF**   — the paper's pair policy; blind to C > 2, so its
//!   rows are the flat control across the cap axis.
//! * **SJF-FFS**    — first-fit sharing, packs up to C residents by
//!   memory headroom alone.
//! * **SJF-BSBF-k** — the k-way generalization; admits a third (fourth,
//!   …) resident only when the composed-interference share-set JCT
//!   beats exclusive waiting.
//!
//! Expected shape: raising C from 2 to 3 strictly lowers mean queueing
//! for SJF-BSBF-k (asserted — CI's `share-cap-smoke` runs this
//! example), with diminishing returns at C = 4 as memory headroom runs
//! out.
//!
//! Run: `cargo run --release --example share_cap_sweep`

use wise_share::campaign::{self, Axes, CampaignSpec};

fn main() -> anyhow::Result<()> {
    let mut spec = CampaignSpec::new("share-cap-sweep");
    spec.policies = vec![
        "SJF-BSBF".to_string(),
        "SJF-FFS".to_string(),
        "SJF-BSBF-k".to_string(),
    ];
    spec.axes = Axes {
        load_factors: vec![2.0],
        job_counts: vec![120],
        gpu_counts: Vec::new(), // the 16×4 simulation cluster
        topologies: Vec::new(),
        workloads: vec!["small-job-flood".to_string()],
        estimators: Vec::new(),
        share_caps: vec![2, 3, 4],
        seeds: vec![1, 2],
        jobs_scale_load_baseline: None,
    };
    let res = campaign::execute(&spec, 0)?;
    if res.n_failures > 0 {
        print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
        anyhow::bail!(
            "{} of {} runs failed (see FAILED lines above)",
            res.n_failures,
            res.n_runs
        );
    }

    // Compact matrix: seed-averaged mean queueing delay (s) per (C, policy).
    print!("C");
    for p in &spec.policies {
        print!(",{p}");
    }
    println!();
    let queue = |cap: usize, policy: &str| {
        res.cells
            .iter()
            .find(|c| c.key.share_cap == cap && c.key.policy == policy)
            .expect("every (cap, policy) cell exists")
            .all
            .avg_queue_s
            .mean()
    };
    for cap in [2usize, 3, 4] {
        print!("{cap}");
        for p in &spec.policies {
            print!(",{:.1}", queue(cap, p));
        }
        println!();
    }

    // The smoke property CI gates on: under a flood of small polite jobs a
    // third co-resident must strictly reduce k-way queueing vs the pair cap.
    let (q2, q3) = (queue(2, "SJF-BSBF-k"), queue(3, "SJF-BSBF-k"));
    assert!(
        q3 < q2,
        "C=3 must strictly lower SJF-BSBF-k mean queueing: {q3:.1}s vs {q2:.1}s"
    );
    println!(
        "\nC=3 lowers SJF-BSBF-k mean queueing by {:.1}% vs the paper's C=2",
        (1.0 - q3 / q2) * 100.0
    );

    // Full seed-averaged tables with 95% CIs, one block per share cap.
    print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
    println!("{} runs in {:.1}s wall", res.n_runs, res.wall_s);
    Ok(())
}

//! Topology sweep: the same workload across cluster *shapes*.
//!
//! Part 1 shows the mechanism: one (running, arriving) pair evaluated by
//! Algorithm 2 with the `GangSpan` of two concrete placements on the
//! heterogeneous 2-tier shape — consolidated on one NVLink node vs
//! scattered over four 10 Gbps nodes. The pair-JCT estimate (Alg. 1
//! line 14's sort key) visibly moves with locality, which is exactly what
//! the flat-switch model of the paper cannot express.
//!
//! Part 2 runs a campaign over the `topologies` axis — the paper's
//! uniform 16×4 cluster, the same shape with NVLink intra-node links, and
//! the heterogeneous 2-tier shape — and prints one seed-averaged report
//! block per cluster shape.
//!
//! Run: `cargo run --release --example topology_sweep`

use wise_share::campaign::{self, Axes, CampaignSpec};
use wise_share::cluster::topology;
use wise_share::jobs::{JobRecord, JobSpec};
use wise_share::pair::batch_size_scaling_placed;
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::ModelKind;

fn main() -> anyhow::Result<()> {
    // --- 1) GangSpan moves the Theorem-1 arithmetic ----------------------
    let topo = topology::by_name("hetero-16x4-2tier").expect("known shape");
    let running = JobRecord::new(JobSpec {
        id: 0,
        model: ModelKind::ImageNet,
        gpus: 4,
        iterations: 4000,
        batch: 32,
        arrival_s: 0.0,
        est_factor: 1.0,
    });
    let newcomer = JobRecord::new(JobSpec {
        id: 1,
        model: ModelKind::Ncf,
        gpus: 4,
        iterations: 3000,
        batch: 4096,
        arrival_s: 10.0,
        est_factor: 1.0,
    });
    let xi = InterferenceModel::new();
    let consolidated = topo.span_of(&[0, 1, 2, 3]); // one reference node
    let scattered = topo.span_of(&[0, 4, 8, 12]); // four nodes, inter tier
    println!("Algorithm 2 on (NCF arriving, ImageNet running), 4-GPU gang:");
    let mut jcts = Vec::new();
    for (label, span) in [
        ("consolidated, 1 node x NVLink intra", &consolidated),
        ("scattered,    4 nodes x 10 Gbps    ", &scattered),
    ] {
        let cfg = batch_size_scaling_placed(
            &newcomer, &running, 4, 11.0, &xi, true, span, span,
        )
        .expect("pair is memory-feasible");
        println!(
            "  {label}: share={} pair mean JCT {:.0}s (nodes={}, {} Gbps)",
            cfg.share, cfg.pair_jct, span.nodes, span.bandwidth_gbps
        );
        jcts.push(cfg.pair_jct);
    }
    assert!(
        jcts[0] < jcts[1],
        "consolidation must improve the pair-JCT estimate"
    );
    println!(
        "  -> locality changes the benefit estimate by {:.1}%\n",
        (jcts[1] / jcts[0] - 1.0) * 100.0
    );

    // --- 2) campaign across cluster shapes -------------------------------
    let mut spec = CampaignSpec::new("topology-sweep");
    spec.policies =
        vec!["SJF".to_string(), "SJF-FFS".to_string(), "SJF-BSBF".to_string()];
    spec.axes = Axes {
        load_factors: vec![1.5],
        job_counts: vec![60],
        gpu_counts: Vec::new(),
        topologies: vec![
            "uniform-16x4".to_string(),
            "uniform-16x4-nvlink".to_string(),
            "hetero-16x4-2tier".to_string(),
        ],
        workloads: Vec::new(),
        estimators: Vec::new(),
        share_caps: Vec::new(),
        seeds: vec![1, 2],
        jobs_scale_load_baseline: None,
    };
    let res = campaign::execute(&spec, 0)?;
    print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
    println!("{} runs in {:.1}s wall", res.n_runs, res.wall_s);
    if res.n_failures > 0 {
        anyhow::bail!(
            "{} of {} runs failed (see FAILED lines above)",
            res.n_failures,
            res.n_runs
        );
    }
    Ok(())
}

//! Fig. 6a reproduction: average JCT vs workload intensity.
//!
//! The paper scales the 240-job baseline by 0.5x-2x (120-480 jobs, arrival
//! density scaled with count). Expected shape: the elastic (Pollux-like)
//! policy wins at light load, loses its edge as the cluster saturates, and
//! SJF-BSBF stays lowest (or close) across the sweep by shrinking queueing
//! via wise sharing.
//!
//! Run: `cargo run --release --example workload_sweep`

use wise_share::cluster::ClusterConfig;
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::perf::interference::InterferenceModel;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sim::{engine, metrics};

fn main() -> anyhow::Result<()> {
    print!("jobs");
    for name in POLICY_NAMES {
        print!(",{name}");
    }
    println!();
    for scale in [0.5, 1.0, 1.5, 2.0] {
        let n_jobs = (240.0 * scale) as usize;
        let mut tcfg = TraceConfig::simulation(n_jobs, 1);
        tcfg.load_factor = scale; // density scales with job count (Fig. 6a)
        let jobs = trace::generate(&tcfg);
        print!("{n_jobs}");
        for name in POLICY_NAMES {
            let mut p = sched::by_name(name).unwrap();
            let out = engine::run(
                ClusterConfig::simulation(),
                &jobs,
                InterferenceModel::new(),
                p.as_mut(),
            )?;
            let s = metrics::summarize(name, &out.jobs, out.makespan_s);
            print!(",{:.3}", s.all.avg_jct_s / 3600.0);
        }
        println!();
    }
    println!("\nvalues: average JCT in hours; expect Pollux best at 120 jobs,");
    println!("SJF-BSBF best (or tied) from 240 jobs upward.");
    Ok(())
}

//! Fig. 6a reproduction: average JCT vs workload intensity, driven by the
//! [`wise_share::campaign`] paper preset instead of a hand-rolled sweep
//! loop.
//!
//! The paper scales the 240-job baseline by 0.5×–2× (120–480 jobs, arrival
//! density scaled with count); the preset runs that grid for all six
//! policies over 3 seeds on a worker pool. Expected shape: the elastic
//! (Pollux-like) policy wins at light load, loses its edge as the cluster
//! saturates, and SJF-BSBF stays lowest (or close) across the sweep by
//! shrinking queueing via wise sharing.
//!
//! Run: `cargo run --release --example workload_sweep`

use wise_share::campaign::{self, CampaignSpec};
use wise_share::sched::PAPER_POLICY_NAMES;

fn main() -> anyhow::Result<()> {
    let spec = CampaignSpec::paper_preset();
    let res = campaign::execute(&spec, 0)?;
    if res.n_failures > 0 {
        print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
        anyhow::bail!("{} of {} runs failed (see FAILED lines above)", res.n_failures, res.n_runs);
    }

    // Compact Fig. 6a matrix: seed-averaged avg JCT (hours) per cell.
    print!("jobs");
    for name in PAPER_POLICY_NAMES {
        print!(",{name}");
    }
    println!();
    let mut jobs_axis: Vec<usize> = res.cells.iter().map(|c| c.key.n_jobs).collect();
    jobs_axis.dedup();
    for n_jobs in jobs_axis {
        print!("{n_jobs}");
        for name in PAPER_POLICY_NAMES {
            let cell = res
                .cells
                .iter()
                .find(|c| c.key.n_jobs == n_jobs && c.key.policy == name)
                .expect("every (jobs, policy) cell exists");
            print!(",{:.3}", cell.all.avg_jct_s.mean() / 3600.0);
        }
        println!();
    }
    println!("\nvalues: average JCT in hours; expect Pollux best at 120 jobs,");
    println!("SJF-BSBF best (or tied) from 240 jobs upward.\n");

    // Full seed-averaged tables with 95% CIs, one block per intensity.
    print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
    Ok(())
}

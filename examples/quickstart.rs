//! Quickstart: the 60-second tour of the public API.
//!
//! Generates a small Philly-like trace, runs it under plain SJF and under
//! the paper's SJF-BSBF on the simulated 16-GPU cluster, and prints the
//! paper-style summary table plus one concrete sharing decision (Theorem 1
//! + Algorithm 2) so you can see the mechanism itself — then implements a
//! minimal custom policy against the `sched_core` event API (the README
//! "writing a policy" walkthrough) and runs it on the same trace.
//!
//! Run: `cargo run --release --example quickstart`

use wise_share::cluster::{placement, ClusterConfig};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::JobRecord;
use wise_share::pair::batch_size_scaling;
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::ModelKind;
use wise_share::report;
use wise_share::sched;
use wise_share::sched_core::{Event, Policy, SchedContext, Txn};
use wise_share::sim::{engine, metrics};

/// A complete custom policy in ~20 lines: greedy arrival-order exclusive
/// placement (no sharing, no HOL blocking). `on_event` fires at every
/// arrival / completion / restart-eligibility (and tick, if
/// `tick_interval` is set); it reads the context's incrementally cached
/// `pending()` set and returns a `Txn` of decisions, which the backend —
/// simulator or physical coordinator — validates and applies through the
/// shared `sched_core` transaction layer.
struct Greedy;

impl Policy for Greedy {
    fn name(&self) -> &'static str {
        "Greedy"
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        let mut txn = Txn::new();
        let mut plan = ctx.overlay(); // hypothetical placements, no deep copy
        for &id in ctx.pending() {
            if let Some(gpus) =
                placement::consolidated_free(&plan, ctx.jobs[id].spec.gpus)
            {
                plan.allocate(id, &gpus);
                txn.start(id, gpus, 1); // exclusive: accumulation step 1
            }
        }
        txn
    }
}

fn main() -> anyhow::Result<()> {
    // --- 1) one explicit pair decision: the heart of SJF-BSBF ------------
    let running = JobRecord::new(wise_share::jobs::JobSpec {
        id: 0,
        model: ModelKind::Cifar10,
        gpus: 4,
        iterations: 4000,
        batch: 128,
        arrival_s: 0.0,
        est_factor: 1.0,
    });
    let newcomer = JobRecord::new(wise_share::jobs::JobSpec {
        id: 1,
        model: ModelKind::Bert,
        gpus: 4,
        iterations: 800,
        batch: 16,
        arrival_s: 100.0,
        est_factor: 1.0,
    });
    let xi = InterferenceModel::new();
    let cfg = batch_size_scaling(&newcomer, &running, 4, 11.0, &xi)
        .expect("this pair is memory-feasible");
    println!("Theorem 1 + Algorithm 2 on (BERT@16 arriving, CIFAR10@128 running):");
    println!(
        "  share now (κ=0)? {}   sub-batch b̄ = {} (accumulation s = {})",
        cfg.share, cfg.sub_batch, cfg.accum_step
    );
    println!(
        "  pair mean JCT: overlap {:.0}s vs sequential {:.0}s\n",
        cfg.schedule.overlap_avg, cfg.schedule.sequential_avg
    );

    // --- 2) a small end-to-end scheduling comparison ----------------------
    let jobs = trace::generate(&TraceConfig::simulation(60, 7));
    let mut rows = Vec::new();
    for name in ["SJF", "SJF-FFS", "SJF-BSBF"] {
        let mut policy = sched::by_name(name).unwrap();
        let out = engine::run(
            ClusterConfig::simulation(),
            &jobs,
            InterferenceModel::new(),
            policy.as_mut(),
        )?;
        rows.push(metrics::summarize(name, &out.jobs, out.makespan_s));
    }
    // The custom event-driven policy runs through the same engine.
    let out = engine::run(
        ClusterConfig::simulation(),
        &jobs,
        InterferenceModel::new(),
        &mut Greedy,
    )?;
    rows.push(metrics::summarize("Greedy", &out.jobs, out.makespan_s));
    println!("60-job trace on 16x4 GPUs (hours):");
    println!("{}", report::table34(&rows));
    Ok(())
}

//! Fig. 6b reproduction: inject a constant interference ratio ξ for every
//! sharing pair and compare the two sharing policies.
//!
//! Paper claim: at ξ ≤ 1.25 SJF-BSBF accepts every share (identical to
//! SJF-FFS); at ξ ∈ [1.5, 2.0] BSBF's Theorem-1 refusals cut average JCT
//! by 8-13% relative to FFS.
//!
//! Run: `cargo run --release --example interference_sweep`

use wise_share::cluster::ClusterConfig;
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::perf::interference::InterferenceModel;
use wise_share::sched;
use wise_share::sim::{engine, metrics};

fn main() -> anyhow::Result<()> {
    let jobs = trace::generate(&TraceConfig::simulation(240, 1));
    println!("xi,policy,avg_jct_hrs");
    for xi in [1.0, 1.25, 1.5, 1.75, 2.0] {
        let mut line = format!("{xi}");
        for name in ["SJF-FFS", "SJF-BSBF"] {
            let mut p = sched::by_name(name).unwrap();
            let out = engine::run(
                ClusterConfig::simulation(),
                &jobs,
                InterferenceModel::with_global(xi),
                p.as_mut(),
            )?;
            let s = metrics::summarize(name, &out.jobs, out.makespan_s);
            line += &format!(",{:.3}", s.all.avg_jct_s / 3600.0);
        }
        println!("{line}");
    }
    println!("\ncolumns: xi, SJF-FFS avg JCT (hrs), SJF-BSBF avg JCT (hrs)");
    println!("expect: equal at xi <= 1.25; BSBF ~8-13% lower at xi in [1.5, 2.0]");
    Ok(())
}

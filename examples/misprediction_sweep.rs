//! Robustness check the paper leaves open: does SJF-BSBF's sharing
//! benefit survive duration misprediction?
//!
//! Every SJF-family policy ranks on duration *estimates* since workload
//! v2; this sweep drives the campaign `estimators` axis over a growing
//! multiplicative log-normal error (`noisy:σ`, σ = 0 … 2) for all six
//! policies on the paper's 240-job / 64-GPU trace, 3 seeds each, and
//! prints the "avg JCT vs estimate error" curves. Expected shape: the
//! oracle column reproduces the paper tables exactly; JCT degrades
//! monotonically-on-average as σ grows for the estimate-driven policies
//! (SJF, SJF-FFS, SJF-BSBF, Pollux), while FIFO and Tiresias — which
//! never consult durations — stay flat, seed noise aside.
//!
//! Run: `cargo run --release --example misprediction_sweep`

use wise_share::campaign::{self, CampaignSpec};
use wise_share::sched::POLICY_NAMES;

/// The σ ladder of the sweep, as campaign estimator specs.
const ESTIMATORS: [&str; 5] = ["oracle", "noisy:0.25", "noisy:0.5", "noisy:1", "noisy:2"];

fn main() -> anyhow::Result<()> {
    let mut spec = CampaignSpec::new("misprediction");
    spec.policies = POLICY_NAMES.iter().map(|s| s.to_string()).collect();
    spec.axes.estimators = ESTIMATORS.iter().map(|s| s.to_string()).collect();
    spec.axes.seeds = vec![1, 2, 3];
    let res = campaign::execute(&spec, 0)?;
    if res.n_failures > 0 {
        print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
        anyhow::bail!(
            "{} of {} runs failed (see FAILED lines above)",
            res.n_failures,
            res.n_runs
        );
    }

    // Compact matrix: seed-averaged avg JCT (hours) per (estimator, policy).
    print!("estimator");
    for name in POLICY_NAMES {
        print!(",{name}");
    }
    println!();
    let jct = |est: &str, policy: &str| -> f64 {
        res.cells
            .iter()
            .find(|c| c.key.estimator == est && c.key.policy == policy)
            .expect("every (estimator, policy) cell exists")
            .all
            .avg_jct_s
            .mean()
    };
    for est in ESTIMATORS {
        print!("{est}");
        for name in POLICY_NAMES {
            print!(",{:.3}", jct(est, name) / 3600.0);
        }
        println!();
    }

    // Monotone-on-average verdict: across the σ ladder, count the rising
    // steps of each estimate-driven policy's curve.
    println!("\nvalues: average JCT in hours; oracle column = the paper tables.");
    for name in ["SJF", "Pollux", "SJF-FFS", "SJF-BSBF"] {
        let curve: Vec<f64> = ESTIMATORS.iter().map(|e| jct(e, name)).collect();
        let rises = curve.windows(2).filter(|w| w[1] >= w[0]).count();
        let trend = if rises * 2 >= curve.len() - 1 { "degrades" } else { "improves?!" };
        println!(
            "{name}: {} of {} steps rise -> JCT {trend} as estimate error grows",
            rises,
            curve.len() - 1
        );
    }
    println!("FIFO and Tiresias never read estimates: their columns are flat.");

    // Full seed-averaged tables with 95% CIs, one block per estimator.
    print!("\n{}", campaign::emit::markdown(&spec.name, &res.cells));
    Ok(())
}

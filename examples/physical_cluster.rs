//! **End-to-end physical run** — the all-layers-compose driver (deliverable
//! (b) + DESIGN.md §7): the paper's 30-job physical workload, scheduled by
//! SJF-BSBF, where every iteration of every job is a *real* AOT-compiled
//! XLA train-step of the transformer LM executed through PJRT by the
//! emulated-GPU worker threads. Per-job loss curves are written to
//! `physical_loss.csv` and a Table-II-style summary is printed.
//!
//! Wall time is compressed (`iter_scale`, `time_compression`) so the run
//! finishes in a few minutes while still executing thousands of PJRT
//! training steps. Results of the recorded run live in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example physical_cluster`
//! Env:  WS_JOBS=30 WS_ITER_SCALE=0.02 WS_POLICY=SJF-BSBF (defaults)

use wise_share::coordinator::{run_physical, write_loss_csv, PhysicalConfig};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::perf::interference::InterferenceModel;
use wise_share::report;
use wise_share::sched;
use wise_share::sim::metrics;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_jobs: usize = env_or("WS_JOBS", 30);
    let iter_scale: f64 = env_or("WS_ITER_SCALE", 0.02);
    let policy_name: String = env_or("WS_POLICY", "SJF-BSBF".to_string());

    let cfg = PhysicalConfig {
        iter_scale,
        time_compression: 240.0,
        ..PhysicalConfig::default()
    };
    let mut tcfg = TraceConfig::physical(1);
    tcfg.n_jobs = n_jobs;
    let jobs = trace::generate(&tcfg);
    let total_iters: u64 = jobs.iter().map(|j| j.iterations).sum();
    println!(
        "physical run: {} jobs ({} trace iterations, x{} scale) on {} emulated GPUs, policy {}",
        jobs.len(),
        total_iters,
        iter_scale,
        cfg.cluster.total_gpus(),
        policy_name
    );

    let mut policy = sched::by_name(&policy_name)
        .unwrap_or_else(|| panic!("unknown policy {policy_name}"));
    let out = run_physical(cfg, &jobs, InterferenceModel::new(), policy.as_mut())?;

    let summary = metrics::summarize(&policy_name, &out.jobs, out.makespan_s);
    println!(
        "\nexecuted {} real PJRT train-steps, wall makespan {:.1}s",
        out.executed_iters, out.makespan_s
    );
    println!("{}", report::table2(&[summary]));

    // Loss curves: prove the jobs actually learn while being scheduled.
    let path = std::path::Path::new("physical_loss.csv");
    write_loss_csv(&out.loss_curves, path)?;
    println!("loss curves ({} points) -> {}", out.loss_curves.len(), path.display());

    // Print a compact first/last loss digest per job for EXPERIMENTS.md.
    println!("\njob  first-loss  last-loss  (learning check)");
    for id in 0..out.jobs.len() {
        let pts: Vec<_> = out.loss_curves.iter().filter(|p| p.job == id).collect();
        if let (Some(first), Some(last)) = (pts.first(), pts.last()) {
            println!(
                "{id:>3}  {:>9.4}  {:>9.4}  {}",
                first.loss,
                last.loss,
                if last.loss < first.loss { "↓" } else { "·" }
            );
        }
    }
    Ok(())
}

"""L1 Pallas kernel: blocked GEMM + fused linear (bias + GELU epilogue).

Hardware adaptation (the paper's jobs ran CUDA DDP; our stand-in training
kernel targets the TPU mental model — see DESIGN.md §6): the K loop is the
innermost grid dimension so each (i, j) output tile stays resident across
the contraction (the revisiting schedule is expressed via BlockSpec index
maps — the TPU analogue of a CUDA threadblock tiling over shared memory),
accumulation is fp32 for the MXU, and default tiles are MXU-shaped
(128x128) clamped to the problem size. `interpret=True` everywhere: the CPU
PJRT plugin cannot run Mosaic custom-calls, and interpret mode lowers to
plain HLO that the Rust runtime executes directly.

`fused_linear` carries a custom_vjp whose backward pass reuses the same
Pallas GEMM (dx = g @ w.T, dw = x.T @ g), so the AOT'd training step runs
Pallas tiles in both fwd and bwd.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile; clamped per call to the (possibly tiny) problem.
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _clamp(tile: int, dim: int) -> int:
    """Largest tile <= `tile` that divides `dim` (grids must tile exactly)."""
    t = max(1, min(tile, dim))
    while dim % t != 0:
        t -= 1
    return t


def _gemm_kernel(x_ref, w_ref, o_ref):
    """One (i, j, k) grid step: o_tile += x_tile @ w_tile.

    The output BlockSpec index map ignores k, so the same o tile is
    revisited across the contraction — Pallas keeps it resident (VMEM on
    TPU) and we accumulate in place in fp32.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k"))
def matmul(
    x: jax.Array,
    w: jax.Array,
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
    tile_k: int = TILE_K,
) -> jax.Array:
    """Blocked Pallas GEMM: [M, K] @ [K, N] -> [M, N], fp32 accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} != {k2}"
    bm, bn, bk = _clamp(tile_m, m), _clamp(tile_n, n), _clamp(tile_k, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


# --- fused linear with custom VJP -------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, gelu: bool = False):
    """x @ w + b with optional (tanh-approx) GELU epilogue, Pallas GEMM core.

    2-D x only ([M, K]); the model reshapes [B, T, K] -> [B*T, K] before
    calling. Backward reuses the Pallas GEMM for both dx and dw.
    """
    y = matmul(x, w) + b
    if gelu:
        y = jax.nn.gelu(y, approximate=True)
    return y


def _fused_linear_fwd(x, w, b, gelu: bool):
    z = matmul(x, w) + b
    y = jax.nn.gelu(z, approximate=True) if gelu else z
    return y, (x, w, z)


def _dgelu(z):
    """d/dz gelu(z), tanh approximation (matches jax.nn.gelu approximate)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
    t = jnp.tanh(c * (z + 0.044715 * z**3))
    dt = (1.0 - t**2) * c * (1.0 + 3 * 0.044715 * z**2)
    return 0.5 * (1.0 + t) + 0.5 * z * dt


def _fused_linear_bwd(gelu: bool, res, g):
    x, w, z = res
    if gelu:
        g = g * _dgelu(z)
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)

"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package has an exact reference here; pytest
(`python/tests/`) asserts allclose between kernel and oracle across a
hypothesis sweep of shapes/dtypes. The oracles are also used as the
backward-pass definitions in the custom_vjp rules (see the kernel modules),
so kernel-vs-ref agreement implies gradient correctness of the whole L2
model up to float error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference for kernels.matmul.matmul: plain fp32-accumulated GEMM."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array, gelu: bool) -> jax.Array:
    """Reference for kernels.matmul.fused_linear: x @ w + b, optionally GELU."""
    y = matmul_ref(x, w) + b.astype(jnp.float32)
    if gelu:
        y = jax.nn.gelu(y, approximate=True)
    return y.astype(x.dtype)


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """Reference for kernels.attention.attention.

    q, k, v: [T, dh] single (batch, head) slice. Softmax over keys with
    optional causal mask; fp32 softmax accumulation.
    """
    t = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.matmul(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.matmul(p, v.astype(jnp.float32)).astype(q.dtype)


def attention_batched_ref(q, k, v, causal: bool = True):
    """[B, H, T, dh] batched version of attention_ref."""
    return jax.vmap(jax.vmap(lambda a, b, c: attention_ref(a, b, c, causal)))(q, k, v)

"""L1 Pallas kernel: blocked causal attention (flash-style online softmax).

One grid step per (batch*head, q-block); the kernel scans key/value blocks
with a running (max, sum) rescale — the classic flash-attention recurrence —
so the full [T, T] logits matrix never materializes. On TPU this is the
VMEM-resident analogue of the CUDA shared-memory flash kernel the DDP jobs
in the paper would run; `interpret=True` lowers it to plain HLO for the CPU
PJRT runtime (see DESIGN.md §6).

Backward is defined via custom_vjp against the reference recomputation
(cheap at our sequence lengths); pytest checks both fwd and grad against
`ref.attention_batched_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, t: int):
    """Flash-style attention for one (bh, q-block) grid point.

    q_ref: [bq, dh]; k_ref, v_ref: [T, dh] (full keys for this bh);
    o_ref: [bq, dh]. Scans key blocks with online-softmax rescaling.
    """
    bq, dh = q_ref.shape
    iq = pl.program_id(1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    q = q_ref[...].astype(jnp.float32) * scale

    n_kb = t // block_k
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k_blk.astype(jnp.float32).T  # [bq, block_k]
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_blk.astype(jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    # Causal: key blocks strictly after this q block contribute nothing.
    upper = n_kb if not causal else (iq * bq + bq + block_k - 1) // block_k
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _attention_fwd_impl(q, k, v, causal: bool, block_q: int, block_k: int):
    b, h, t, dh = q.shape
    bq = max(1, min(block_q, t))
    while t % bq != 0:
        bq -= 1
    bk = max(1, min(block_k, t))
    while t % bk != 0:
        bk -= 1
    qf = q.reshape(b * h, t, dh)
    kf = k.reshape(b * h, t, dh)
    vf = v.reshape(b * h, t, dh)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_k=bk, causal=causal, t=t),
        grid=(b * h, t // bq),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda bh, iq: (bh, iq, 0)),
            pl.BlockSpec((None, t, dh), lambda bh, iq: (bh, 0, 0)),
            pl.BlockSpec((None, t, dh), lambda bh, iq: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda bh, iq: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, t, dh)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Batched multi-head attention, [B, H, T, dh] -> [B, H, T, dh]."""
    return _attention_fwd_impl(q, k, v, causal, block_q, block_k)


def _attention_vjp_fwd(q, k, v, causal, block_q, block_k):
    out = _attention_fwd_impl(q, k, v, causal, block_q, block_k)
    return out, (q, k, v)


def _attention_vjp_bwd(causal, block_q, block_k, res, g):
    q, k, v = res
    # Backward by differentiating the reference recomputation: exact same
    # math as the kernel (softmax(qk^T)v), and T is small in our models.
    _, vjp = jax.vjp(lambda a, b, c: _ref.attention_batched_ref(a, b, c, causal), q, k, v)
    return vjp(g)


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)

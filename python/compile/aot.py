"""AOT: lower the L2 training step to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted artifacts (default config, see model.DEFAULT):

    artifacts/grad_step_mb{b}.hlo.txt   b in MICRO_BATCHES
        inputs : params... (P arrays), x i32[b,T], y i32[b,T]
        outputs: (loss f32[], grads... (P arrays))
    artifacts/accum.hlo.txt             inputs: grads_a..., grads_b... -> sums
    artifacts/apply.hlo.txt             inputs: params..., grads..., hp f32[2]
                                        hp = [lr, 1/s]; outputs: params'
    artifacts/init_params.hlo.txt       inputs: () -> params... (seeded init)
    artifacts/meta.json                 param names/shapes, variants, config

`make artifacts` re-runs this only when python/compile/** changes; Python is
never on the Rust request path.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Sub-batch variants: Algorithm 2 halves the batch b <- b/2 down to 1, so the
# runtime needs one grad_step executable per power-of-two micro-batch.
MICRO_BATCHES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_grad_step(cfg: M.ModelConfig, micro_batch: int) -> str:
    pspecs = [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in M.param_shapes(cfg)
    ]
    xspec = jax.ShapeDtypeStruct((micro_batch, cfg.seq_len), jnp.int32)

    def fn(*args):
        n = len(pspecs)
        params, x, y = list(args[:n]), args[n], args[n + 1]
        return M.grad_step(cfg, params, x, y)

    return to_hlo_text(jax.jit(fn).lower(*pspecs, xspec, xspec))


def lower_accum(cfg: M.ModelConfig) -> str:
    n = len(M.param_shapes(cfg))
    gspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in M.param_shapes(cfg)] * 2
    return to_hlo_text(jax.jit(lambda *g: M.accum(n, *g)).lower(*gspecs))


def lower_apply(cfg: M.ModelConfig) -> str:
    n = len(M.param_shapes(cfg))
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in M.param_shapes(cfg)] * 2
    specs.append(jax.ShapeDtypeStruct((2,), jnp.float32))
    return to_hlo_text(jax.jit(lambda *a: M.apply_update(n, *a)).lower(*specs))


def lower_init(cfg: M.ModelConfig, seed: int = 0) -> str:
    return to_hlo_text(jax.jit(lambda: tuple(M.init_params(cfg, seed))).lower())


def write_meta(cfg: M.ModelConfig, out_dir: str) -> None:
    meta = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "n_params": int(M.n_params(cfg)),
        },
        "param_names": M.param_names(cfg),
        "param_shapes": [list(s) for s in M.param_shapes(cfg)],
        "micro_batches": list(MICRO_BATCHES),
        "artifacts": {
            **{f"grad_step_mb{b}": f"grad_step_mb{b}.hlo.txt" for b in MICRO_BATCHES},
            "accum": "accum.hlo.txt",
            "apply": "apply.hlo.txt",
            "init_params": "init_params.hlo.txt",
        },
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seq-len", type=int, default=M.DEFAULT.seq_len)
    args = ap.parse_args()
    cfg = M.ModelConfig(seq_len=args.seq_len) if args.seq_len != M.DEFAULT.seq_len else M.DEFAULT
    os.makedirs(args.out_dir, exist_ok=True)

    for b in MICRO_BATCHES:
        text = lower_grad_step(cfg, b)
        path = os.path.join(args.out_dir, f"grad_step_mb{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    for name, fn in [("accum", lower_accum), ("apply", lower_apply)]:
        text = fn(cfg)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    text = lower_init(cfg)
    path = os.path.join(args.out_dir, "init_params.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")
    write_meta(cfg, args.out_dir)
    print(f"wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()

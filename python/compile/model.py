"""L2: transformer language model fwd/bwd in JAX, built on the L1 kernels.

This is the *workload* that the Rust coordinator's physical mode actually
executes for every scheduled DL job: a decoder-only transformer LM trained
with SGD on next-token prediction. It is deliberately decomposed into three
AOT-compilable pieces so that **gradient accumulation — the paper's
memory-pressure knob (Algorithm 2's sub-batch size b = B/s) — is owned by
the Rust hot loop**, never by Python:

    grad_step(params, x, y)   -> (loss, grads)        one micro-batch
    accum(grads_a, grads_b)   -> grads_a + grads_b    fold micro-batches
    apply(params, grads, hp)  -> params'              SGD, hp = [lr, 1/s]

Running `apply(params, sum_of_s_micro_grads, [lr, 1/s])` is bit-for-bit the
same update as one full-batch step with batch B = s*b (the property the
paper relies on for "no accuracy degradation"; tested in
python/tests/test_model.py::test_grad_accum_equivalence).

Parameters travel as a *flat list* of arrays in the deterministic order
given by `param_names()`; `aot.py` writes the shapes to
artifacts/meta.json so the Rust runtime can allocate/feed them.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.matmul import fused_linear


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer LM hyper-parameters."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    seq_len: int = 64

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# A "tiny" config for fast pytest runs.
TINY = ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=1, d_ff=64, seq_len=16)
# Default config used by the AOT artifacts / physical-mode executor.
DEFAULT = ModelConfig()


def param_names(cfg: ModelConfig) -> List[str]:
    """Deterministic flat parameter order — the AOT ABI with Rust."""
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1_g", f"l{i}.ln1_b",
            f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2_g", f"l{i}.ln2_b",
            f"l{i}.w1", f"l{i}.b1", f"l{i}.w2", f"l{i}.b2",
        ]
    names += ["lnf_g", "lnf_b", "head"]
    return names


def param_shapes(cfg: ModelConfig) -> List[Tuple[int, ...]]:
    """Shapes matching `param_names` order."""
    d, ff, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    shapes: List[Tuple[int, ...]] = [(v, d), (t, d)]
    for _ in range(cfg.n_layers):
        shapes += [
            (d,), (d,),
            (d, d), (d, d), (d, d), (d, d),
            (d,), (d,),
            (d, ff), (ff,), (ff, d), (d,),
        ]
    shapes += [(d,), (d,), (d, v)]
    return shapes


def n_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for s in param_shapes(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Scaled-normal init, flat list in `param_names` order."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(param_shapes(cfg)))
    out = []
    for key, name, shape in zip(keys, param_names(cfg), param_shapes(cfg)):
        if name.endswith(("_g",)):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", ".b1", ".b2")):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            out.append(
                jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(float(fan_in))
            )
    return out


def _layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def forward(cfg: ModelConfig, params: List[jax.Array], x: jax.Array) -> jax.Array:
    """Logits for token ids x: [B, T] -> [B, T, vocab].

    All dense projections run through the Pallas `fused_linear`; attention
    runs through the Pallas flash kernel. Pre-LN residual blocks.
    """
    names = param_names(cfg)
    p = dict(zip(names, params))
    bsz, t = x.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim

    hdn = p["tok_emb"][x] + p["pos_emb"][None, :t, :]
    for i in range(cfg.n_layers):
        # --- attention block
        a_in = _layer_norm(hdn, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        flat = a_in.reshape(bsz * t, d)
        q = fused_linear(flat, p[f"l{i}.wq"], jnp.zeros((d,)), False)
        k = fused_linear(flat, p[f"l{i}.wk"], jnp.zeros((d,)), False)
        v = fused_linear(flat, p[f"l{i}.wv"], jnp.zeros((d,)), False)

        def heads(z):
            return z.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)

        o = attention(heads(q), heads(k), heads(v), True)
        o = o.transpose(0, 2, 1, 3).reshape(bsz * t, d)
        o = fused_linear(o, p[f"l{i}.wo"], jnp.zeros((d,)), False)
        hdn = hdn + o.reshape(bsz, t, d)
        # --- MLP block
        m_in = _layer_norm(hdn, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
        m = fused_linear(m_in.reshape(bsz * t, d), p[f"l{i}.w1"], p[f"l{i}.b1"], True)
        m = fused_linear(m, p[f"l{i}.w2"], p[f"l{i}.b2"], False)
        hdn = hdn + m.reshape(bsz, t, d)

    hdn = _layer_norm(hdn, p["lnf_g"], p["lnf_b"])
    logits = fused_linear(hdn.reshape(bsz * t, d), p["head"], jnp.zeros((cfg.vocab,)), False)
    return logits.reshape(bsz, t, cfg.vocab)


def loss_fn(cfg: ModelConfig, params: List[jax.Array], x: jax.Array, y: jax.Array):
    """Mean next-token cross-entropy over the batch."""
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# --- The three AOT-compiled entry points ------------------------------------


def grad_step(cfg: ModelConfig, params: List[jax.Array], x: jax.Array, y: jax.Array):
    """One micro-batch: (loss, grads). grads in `param_names` order."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, x, y))(params)
    return (loss, *grads)


def accum(n: int, *grads: jax.Array):
    """Element-wise sum of two flat grad lists (first n + last n)."""
    assert len(grads) == 2 * n
    return tuple(a + b for a, b in zip(grads[:n], grads[n:]))


def apply_update(n: int, *args: jax.Array):
    """SGD: params - lr * (grads * inv_s). args = params(n), grads(n), hp[2].

    hp is a f32[2] array [lr, inv_s]; inv_s = 1/s averages the s
    accumulated micro-batch gradients back to the full-batch mean.
    """
    assert len(args) == 2 * n + 1
    params, grads, hp = args[:n], args[n : 2 * n], args[2 * n]
    lr, inv_s = hp[0], hp[1]
    return tuple(p - lr * (g * inv_s) for p, g in zip(params, grads))

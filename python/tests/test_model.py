"""L2 correctness: model shapes, gradient-accumulation equivalence, training.

The key paper property lives in `test_grad_accum_equivalence`: updating with
the mean of s micro-batch gradients (sub-batch b = B/s) is numerically the
same step as one full-batch update — gradient accumulation preserves
convergence, which is what lets SJF-BSBF shrink sub-batches for GPU sharing
without touching the user's effective batch size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.TINY


def _batch(cfg, bsz, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(k1, (bsz, cfg.seq_len), 0, cfg.vocab, jnp.int32)
    y = jax.random.randint(k2, (bsz, cfg.seq_len), 0, cfg.vocab, jnp.int32)
    return x, y


def test_param_shapes_match_names():
    assert len(M.param_names(CFG)) == len(M.param_shapes(CFG))


def test_param_count_positive_and_scales_with_layers():
    small = M.n_params(M.TINY)
    big = M.n_params(M.ModelConfig())
    assert 0 < small < big


def test_init_params_deterministic():
    a = M.init_params(CFG, seed=7)
    b = M.init_params(CFG, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_forward_shape():
    params = M.init_params(CFG)
    x, _ = _batch(CFG, 2)
    logits = M.forward(CFG, params, x)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_loss_finite_and_near_uniform_at_init():
    params = M.init_params(CFG)
    x, y = _batch(CFG, 4)
    loss = M.loss_fn(CFG, params, x, y)
    # Random init => loss close to ln(vocab).
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.5


def test_grad_step_returns_loss_and_all_grads():
    params = M.init_params(CFG)
    x, y = _batch(CFG, 2)
    out = M.grad_step(CFG, params, x, y)
    assert len(out) == 1 + len(params)
    assert out[0].shape == ()
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape


def test_accum_is_elementwise_sum():
    params = M.init_params(CFG)
    n = len(params)
    doubled = M.accum(n, *params, *params)
    for d, p in zip(doubled, params):
        np.testing.assert_allclose(d, 2 * np.asarray(p), rtol=1e-6)


def test_apply_update_sgd_direction():
    params = M.init_params(CFG)
    n = len(params)
    grads = [jnp.ones_like(p) for p in params]
    hp = jnp.array([0.1, 0.5], jnp.float32)  # lr=0.1, inv_s=0.5
    new = M.apply_update(n, *params, *grads, hp)
    for p, q in zip(params, new):
        np.testing.assert_allclose(np.asarray(q), np.asarray(p) - 0.05, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("s", [2, 4])
def test_grad_accum_equivalence(s):
    """mean of s micro-grads == full-batch grad; update identical."""
    cfg = CFG
    params = M.init_params(cfg)
    n = len(params)
    bsz = 4
    x, y = _batch(cfg, bsz, seed=3)

    # Full-batch step.
    full = M.grad_step(cfg, params, x, y)
    full_grads = list(full[1:])

    # Accumulated micro-batch steps (b = bsz/s).
    b = bsz // s
    acc = None
    for i in range(s):
        out = M.grad_step(cfg, params, x[i * b : (i + 1) * b], y[i * b : (i + 1) * b])
        g = list(out[1:])
        acc = g if acc is None else list(M.accum(n, *acc, *g))

    hp = jnp.array([0.5, 1.0 / s], jnp.float32)
    via_accum = M.apply_update(n, *params, *acc, hp)
    hp_full = jnp.array([0.5, 1.0], jnp.float32)
    via_full = M.apply_update(n, *params, *full_grads, hp_full)
    for a, f in zip(via_accum, via_full):
        np.testing.assert_allclose(np.asarray(a), np.asarray(f), rtol=2e-4, atol=2e-4)


def test_training_reduces_loss():
    """A few SGD steps on a fixed batch must reduce the loss (memorization)."""
    cfg = CFG
    params = list(M.init_params(cfg))
    n = len(params)
    x, y = _batch(cfg, 4, seed=1)
    first = None
    hp = jnp.array([0.5, 1.0], jnp.float32)
    for _ in range(8):
        out = M.grad_step(cfg, params, x, y)
        loss, grads = out[0], list(out[1:])
        if first is None:
            first = float(loss)
        params = list(M.apply_update(n, *params, *grads, hp))
    out = M.grad_step(cfg, params, x, y)
    assert float(out[0]) < first * 0.8, (first, float(out[0]))

"""AOT path: HLO text emission is well-formed and meta matches the model ABI.

Uses the TINY config (fast); `make artifacts` exercises the DEFAULT config.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

CFG = M.TINY


def test_grad_step_hlo_text_parses_back(tmp_path):
    text = aot.lower_grad_step(CFG, micro_batch=2)
    assert text.startswith("HloModule"), text[:80]
    # One ENTRY parameter per model param + x + y. (Nested fusion
    # computations have their own parameter(k) lines, so take the max index.)
    import re

    idx = [int(m) for m in re.findall(r"parameter\((\d+)\)", text)]
    assert max(idx) + 1 == len(M.param_shapes(CFG)) + 2


def test_accum_hlo_has_adds():
    text = aot.lower_accum(CFG)
    assert text.startswith("HloModule")
    assert text.count(" add(") >= len(M.param_shapes(CFG))


def test_apply_hlo_has_hp_param():
    text = aot.lower_apply(CFG)
    assert "f32[2]" in text  # the [lr, inv_s] hyper-parameter vector


def test_init_hlo_no_params():
    text = aot.lower_init(CFG)
    assert text.startswith("HloModule")
    # The ENTRY computation of the init program takes no parameters
    # (nested fusion/reduce computations may still have parameter lines).
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    entry_block = []
    for l in lines[start + 1 :]:
        if l.strip() == "}":
            break
        entry_block.append(l)
    assert entry_block, "empty ENTRY block"
    assert not any("parameter(" in l for l in entry_block), entry_block[:5]


def test_meta_json_roundtrip(tmp_path):
    aot.write_meta(CFG, str(tmp_path))
    meta = json.load(open(tmp_path / "meta.json"))
    assert meta["param_names"] == M.param_names(CFG)
    assert [tuple(s) for s in meta["param_shapes"]] == list(M.param_shapes(CFG))
    assert meta["micro_batches"] == list(aot.MICRO_BATCHES)
    assert meta["model"]["n_params"] == M.n_params(CFG)


def test_grad_step_execute_equals_direct_call():
    """Compiling the lowered module and executing == calling grad_step."""
    params = M.init_params(CFG, seed=0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    x = jax.random.randint(k1, (2, CFG.seq_len), 0, CFG.vocab, jnp.int32)
    y = jax.random.randint(k2, (2, CFG.seq_len), 0, CFG.vocab, jnp.int32)

    def fn(*args):
        n = len(params)
        return M.grad_step(CFG, list(args[:n]), args[n], args[n + 1])

    direct = fn(*params, x, y)
    jitted = jax.jit(fn)(*params, x, y)
    import numpy as np

    for d, j in zip(direct, jitted):
        np.testing.assert_allclose(np.asarray(d), np.asarray(j), rtol=1e-5, atol=1e-5)

"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes/dtypes/tile sizes; every property asserts
allclose against `compile.kernels.ref`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as at
from compile.kernels import matmul as mk
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    tile=st.sampled_from([8, 16, 32, 128]),
)
def test_matmul_matches_ref_shapes(m, k, n, tile):
    x = _rand(m * 7 + 1, (m, k), jnp.float32)
    w = _rand(n * 13 + 2, (k, n), jnp.float32)
    got = mk.matmul(x, w, tile_m=tile, tile_n=tile, tile_k=tile)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand(3, (32, 24), dtype)
    w = _rand(4, (24, 40), dtype)
    got = np.asarray(mk.matmul(x, w), np.float32)
    want = np.asarray(ref.matmul_ref(x, w), np.float32)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_matmul_identity():
    x = _rand(5, (16, 16), jnp.float32)
    eye = jnp.eye(16, dtype=jnp.float32)
    np.testing.assert_allclose(mk.matmul(x, eye), x, rtol=1e-6, atol=1e-6)


def test_matmul_rejects_bad_contraction():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 7))
    with pytest.raises(AssertionError):
        mk.matmul(x, w)


def test_matmul_tile_clamp_indivisible():
    # 30x30 with tile 128 must clamp to a divisor, not crash.
    x = _rand(6, (30, 30), jnp.float32)
    w = _rand(7, (30, 30), jnp.float32)
    np.testing.assert_allclose(
        mk.matmul(x, w), ref.matmul_ref(x, w), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------- fused_linear


@settings(**SETTINGS)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 48),
    n=st.integers(1, 64),
    gelu=st.booleans(),
)
def test_fused_linear_matches_ref(m, k, n, gelu):
    x = _rand(m + 11, (m, k), jnp.float32)
    w = _rand(n + 17, (k, n), jnp.float32)
    b = _rand(k + 23, (n,), jnp.float32)
    got = mk.fused_linear(x, w, b, gelu)
    want = ref.linear_ref(x, w, b, gelu)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("gelu", [False, True])
def test_fused_linear_grads_match_autodiff_of_ref(gelu):
    x = _rand(1, (24, 16), jnp.float32)
    w = _rand(2, (16, 32), jnp.float32)
    b = _rand(3, (32,), jnp.float32)

    def f_kernel(x, w, b):
        return jnp.sum(jnp.sin(mk.fused_linear(x, w, b, gelu)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.linear_ref(x, w, b, gelu)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([8, 16, 24, 64]),
    dh=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    bq=st.sampled_from([4, 8, 16, 64]),
)
def test_attention_matches_ref(b, h, t, dh, causal, bq):
    q = _rand(b * 100 + t, (b, h, t, dh), jnp.float32)
    k = _rand(h * 100 + t + 1, (b, h, t, dh), jnp.float32)
    v = _rand(dh * 100 + t + 2, (b, h, t, dh), jnp.float32)
    got = at.attention(q, k, v, causal, bq, bq)
    want = ref.attention_batched_ref(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_attention_causality():
    """Future keys must not influence causal attention output."""
    b, h, t, dh = 1, 2, 16, 8
    q = _rand(1, (b, h, t, dh), jnp.float32)
    k = _rand(2, (b, h, t, dh), jnp.float32)
    v = _rand(3, (b, h, t, dh), jnp.float32)
    base = at.attention(q, k, v, True, 8, 8)
    # Perturb the last key/value; only the last query position may change.
    k2 = k.at[:, :, -1, :].add(100.0)
    v2 = v.at[:, :, -1, :].add(100.0)
    pert = at.attention(q, k2, v2, True, 8, 8)
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[:, :, -1], pert[:, :, -1])


def test_attention_softmax_rows_are_convex_combinations():
    """Non-causal attention output rows lie within [min(v), max(v)] per dim."""
    b, h, t, dh = 2, 2, 16, 8
    q = _rand(4, (b, h, t, dh), jnp.float32)
    k = _rand(5, (b, h, t, dh), jnp.float32)
    v = _rand(6, (b, h, t, dh), jnp.float32)
    out = np.asarray(at.attention(q, k, v, False, 8, 8))
    vmin = np.asarray(v).min(axis=2, keepdims=True) - 1e-4
    vmax = np.asarray(v).max(axis=2, keepdims=True) + 1e-4
    assert (out >= vmin).all() and (out <= vmax).all()


def test_attention_grad_matches_ref_grad():
    b, h, t, dh = 1, 2, 16, 8
    q = _rand(7, (b, h, t, dh), jnp.float32)
    k = _rand(8, (b, h, t, dh), jnp.float32)
    v = _rand(9, (b, h, t, dh), jnp.float32)

    gk = jax.grad(lambda q, k, v: jnp.sum(at.attention(q, k, v, True, 8, 8) ** 2), (0, 1, 2))(q, k, v)
    gr = jax.grad(
        lambda q, k, v: jnp.sum(ref.attention_batched_ref(q, k, v, True) ** 2), (0, 1, 2)
    )(q, k, v)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_attention_block_size_invariance():
    """Result must not depend on the flash block decomposition."""
    b, h, t, dh = 1, 1, 64, 16
    q = _rand(10, (b, h, t, dh), jnp.float32)
    k = _rand(11, (b, h, t, dh), jnp.float32)
    v = _rand(12, (b, h, t, dh), jnp.float32)
    o1 = at.attention(q, k, v, True, 64, 64)
    o2 = at.attention(q, k, v, True, 8, 16)
    o3 = at.attention(q, k, v, True, 16, 8)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(o1, o3, rtol=2e-5, atol=2e-5)

//! First-party stand-in for the `anyhow` crate, vendored so the build is
//! fully offline (the build environment has no crates.io access; see
//! DESIGN.md §4). Implements the subset this repository uses:
//!
//! * [`Error`] — a boxed-source, message-carrying error type,
//! * [`Result<T>`] — `Result<T, Error>`,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`] / [`bail!`] — format-style error construction.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: the blanket `From<E: std::error::Error>` conversion
//! (what makes `?` work on std errors) is only coherent because of that.

use std::error::Error as StdError;
use std::fmt;

/// `Result` specialized to [`Error`], matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error with an optional boxed source.
///
/// Context added via [`Context`] is folded into the message front-to-back,
/// so `Display` shows `"outer context: inner cause"` like the real crate's
/// `{:#}` rendering.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Prepend a context layer to the message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The underlying cause, if this error wraps a std error.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        match &self.source {
            Some(b) => Some(b.as_ref()),
            None => None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cause = self.source.as_deref().and_then(StdError::source);
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {c}")?;
            cause = c.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Marker type parameter for the `Result<T, Error>` impl of [`Context`]
/// (disambiguates it from the blanket std-error impl without negative
/// reasoning — the same role `Infallible` plays for `Option`).
pub enum ChainMarker {}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
///
/// The second type parameter only disambiguates the three impls; it never
/// appears in the methods.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, ChainMarker> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`anyhow!`] error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn context_layers_fold_into_display() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
        let e2: Result<()> = Err(e);
        let e2 = e2.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(e2.to_string(), "step 2: reading file: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert!(Some(3u32).context("present").is_ok());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(inner().unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: u32) -> Result<()> {
            if x > 3 {
                bail!("x too big: {x}");
            }
            Ok(())
        }
        assert_eq!(f(5).unwrap_err().to_string(), "x too big: 5");
        assert!(f(1).is_ok());
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(e.to_string(), "1 + 2");
        let e = anyhow!(std::fmt::Error);
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn source_chain_preserved() {
        let e = io_err().context("ctx").unwrap_err();
        assert!(e.source().is_some());
    }
}

//! Offline stub of the `xla` PJRT bindings (DESIGN.md §4).
//!
//! The physical-mode coordinator and runtime are written against the
//! vendored `xla_extension` binding crate, which needs the native XLA
//! runtime — not available in this offline build environment. This stub
//! keeps the whole crate compiling and the simulator/campaign paths fully
//! functional:
//!
//! * host-side [`Literal`] construction/reshape/readback work for real,
//! * anything touching the device — [`PjRtClient::cpu`], compilation,
//!   execution — returns a descriptive [`Error`] at **runtime** instead of
//!   failing the build, so `wise-share physical` degrades into a clear
//!   "runtime unavailable" message while `cargo test -q` stays green
//!   (artifact-dependent tests skip themselves when the runtime is absent).
//!
//! Swapping the real binding back in is a one-line change in Cargo.toml;
//! the API surface below matches the subset the repo uses.

use std::fmt;
use std::rc::Rc;

/// Error type matching the binding crate's role; implements
/// `std::error::Error`, so `?` converts it into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT native runtime is not available in this offline build \
             (vendor/xla is a stub; physical mode needs the real xla_extension binding)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Elems {
    I32(Vec<i32>),
    F32(Vec<f32>),
    Tuple(Vec<Literal>),
}

/// Native element types transferable to/from a [`Literal`].
pub trait Element: Copy {
    #[doc(hidden)]
    fn wrap(vals: &[Self]) -> Elems;
    #[doc(hidden)]
    fn unwrap(elems: &Elems) -> Option<Vec<Self>>;
}

impl Element for i32 {
    fn wrap(vals: &[Self]) -> Elems {
        Elems::I32(vals.to_vec())
    }
    fn unwrap(elems: &Elems) -> Option<Vec<Self>> {
        match elems {
            Elems::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Element for f32 {
    fn wrap(vals: &[Self]) -> Elems {
        Elems::F32(vals.to_vec())
    }
    fn unwrap(elems: &Elems) -> Option<Vec<Self>> {
        match elems {
            Elems::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side array (or tuple) literal. Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: Elems,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a native slice.
    pub fn vec1<T: Element>(vals: &[T]) -> Literal {
        Literal { elems: T::wrap(vals), dims: vec![vals.len() as i64] }
    }

    /// Tuple literal (what compiled programs return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal { elems: Elems::Tuple(parts), dims: vec![n] }
    }

    fn len(&self) -> usize {
        match &self.elems {
            Elems::I32(v) => v.len(),
            Elems::F32(v) => v.len(),
            Elems::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret the element buffer under new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { elems: self.elems.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements back out as a flat host vector.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.elems)
            .ok_or_else(|| Error(format!("to_vec: element type mismatch for {:?}", self.dims)))
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.elems {
            Elems::Tuple(v) => Ok(v),
            other => Err(Error(format!("to_tuple: not a tuple literal ({other:?})"))),
        }
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact. Parsing/ID-fixup happens in the real
    /// binding; the stub only checks the file is readable.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { _text: text }),
            Err(e) => Err(Error(format!("reading HLO text {path:?}: {e}"))),
        }
    }
}

/// A computation ready for compilation (opaque in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. `Rc` keeps the stub `!Send`, matching the real
/// binding's constraint that each worker thread owns its own client.
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled program handle (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed or owned literal arguments
    /// (`execute::<Literal>(&[])`, `execute::<&Literal>(&args)`).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_destructure() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.5f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.5]);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn device_paths_report_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("not available"));
    }
}
